//! Stack-trace interning map — the analogue of `BPF_MAP_TYPE_STACK_TRACE`.
//!
//! The real GAPP never ships raw stacks through the perf buffer: the
//! `sched_switch` probe calls `bpf_get_stackid()`, which walks the
//! stack, hashes the frames and stores them in a bounded kernel map,
//! returning a small integer id. Ring-buffer records then carry the id
//! (4 bytes) instead of up to 127 frames, and user space resolves ids
//! back to frames only when a call path actually reaches the report.
//! That interning is a big part of the paper's ~4% overhead claim.
//!
//! This map reproduces the mechanism: frames are stored once in a flat
//! arena, an FxHash bucket index (hash → chain of candidate ids) gives
//! O(1) expected lookup with exact frame comparison, and capacity is
//! bounded. What happens at capacity is the [`EvictPolicy`]:
//!
//! * [`EvictPolicy::DropNew`] (default, the `bpf_get_stackid` `-ENOMEM`
//!   behaviour): further *new* stacks are dropped and counted, while
//!   known stacks keep resolving.
//! * [`EvictPolicy::Lru`]: the least-recently-seen stack is evicted and
//!   its id recycled — what a long-running daemon under the streaming
//!   analyzer needs so the map never saturates. A recycled id resolves
//!   to its *new* owner, so consumers must not key long-lived state on
//!   raw ids: the streaming analyzer re-interns each window snapshot
//!   into a stable userspace map at window close, leaving only the
//!   within-window capture-to-read race (the same race a real BPF
//!   stack-map consumer has between `bpf_get_stackid` and reading the
//!   map).
//!
//! Ids are dense (0, 1, 2, …) in first-capture order, so the user-space
//! merge can group by id with a dense table.

use crate::util::fxhash::{hash_words, FxHashMap};

/// Sentinel id returned when the map is full and the stack is new
/// (mirrors `bpf_get_stackid()` returning `-ENOMEM`). Resolves to an
/// empty frame slice.
pub const STACK_ID_DROPPED: u32 = u32::MAX;

const NO_NEXT: u32 = u32::MAX;

/// What to do with a *new* stack once `max_entries` distinct stacks
/// exist (the knob a deployment turns for long-running daemons).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EvictPolicy {
    /// Drop the new stack and count it (`bpf_get_stackid` `-ENOMEM`).
    #[default]
    DropNew,
    /// Evict the least-recently-seen stack and recycle its id.
    Lru,
}

/// Hit/insert/drop counters for one stack map.
#[derive(Clone, Copy, Debug, Default)]
pub struct StackMapStats {
    /// Lookups that found an existing id.
    pub hits: u64,
    /// New stacks interned (under LRU this counts recycles too, so it
    /// may exceed the number of distinct live ids).
    pub inserts: u64,
    /// New stacks dropped because the map was full.
    pub drops: u64,
    /// Stacks evicted to recycle their id (LRU policy only).
    pub evictions: u64,
}

/// Bounded stack-trace interner: `&[u64]` frames → dense `u32` id.
#[derive(Debug)]
pub struct StackMap {
    name: &'static str,
    max_entries: usize,
    policy: EvictPolicy,
    /// Flat frame arena; spans index into it.
    frames: Vec<u64>,
    /// id → (offset, len) into `frames`.
    spans: Vec<(u32, u32)>,
    /// id → words reserved for it in the arena. A recycled id reuses its
    /// reservation when the new stack fits and grows it otherwise, so
    /// the reservation is monotone and total arena size stays bounded by
    /// Σ per-id maximum length.
    caps: Vec<u32>,
    /// id → next id in the same hash bucket (NO_NEXT terminates).
    chain: Vec<u32>,
    /// frame-hash → chain head id.
    heads: FxHashMap<u64, u32>,
    /// Intrusive recency list (LRU policy): prev points toward the
    /// most-recently-seen end, next toward the least-recently-seen end.
    lru_prev: Vec<u32>,
    lru_next: Vec<u32>,
    lru_head: u32,
    lru_tail: u32,
    pub stats: StackMapStats,
}

impl StackMap {
    pub fn new(name: &'static str, max_entries: usize) -> StackMap {
        StackMap::with_policy(name, max_entries, EvictPolicy::DropNew)
    }

    pub fn with_policy(
        name: &'static str,
        max_entries: usize,
        policy: EvictPolicy,
    ) -> StackMap {
        StackMap {
            name,
            max_entries,
            policy,
            frames: Vec::new(),
            spans: Vec::new(),
            caps: Vec::new(),
            chain: Vec::new(),
            heads: FxHashMap::default(),
            lru_prev: Vec::new(),
            lru_next: Vec::new(),
            lru_head: NO_NEXT,
            lru_tail: NO_NEXT,
            stats: StackMapStats::default(),
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn policy(&self) -> EvictPolicy {
        self.policy
    }

    /// Intern a stack, returning its id — an existing id when the exact
    /// frame sequence was seen before, a fresh dense id otherwise. At
    /// capacity the [`EvictPolicy`] decides: [`STACK_ID_DROPPED`]
    /// (drop-new, counted) or a recycled id (LRU). The steady-state
    /// path (known stack) performs no allocation.
    pub fn intern(&mut self, stack: &[u64]) -> u32 {
        let h = hash_words(stack);
        let mut cur = self.heads.get(&h).copied();
        while let Some(id) = cur {
            if self.frames_of(id) == stack {
                self.stats.hits += 1;
                if self.policy == EvictPolicy::Lru {
                    self.lru_touch(id);
                }
                return id;
            }
            let next = self.chain[id as usize];
            cur = if next == NO_NEXT { None } else { Some(next) };
        }
        if self.spans.len() < self.max_entries
            && self.frames.len() + stack.len() <= u32::MAX as usize
        {
            return self.insert_fresh(h, stack);
        }
        match self.policy {
            EvictPolicy::DropNew => {
                self.stats.drops += 1;
                STACK_ID_DROPPED
            }
            EvictPolicy::Lru => self.evict_and_recycle(h, stack),
        }
    }

    /// Fresh insert below capacity: append to the arena, link the bucket
    /// chain (new entry becomes the head) and the recency list.
    fn insert_fresh(&mut self, h: u64, stack: &[u64]) -> u32 {
        let id = self.spans.len() as u32;
        let offset = self.frames.len() as u32;
        self.frames.extend_from_slice(stack);
        self.spans.push((offset, stack.len() as u32));
        self.caps.push(stack.len() as u32);
        let prev_head = self.heads.insert(h, id).unwrap_or(NO_NEXT);
        self.chain.push(prev_head);
        self.lru_prev.push(NO_NEXT);
        self.lru_next.push(NO_NEXT);
        if self.policy == EvictPolicy::Lru {
            self.lru_link_front(id);
        }
        self.stats.inserts += 1;
        id
    }

    /// LRU at capacity: evict the least-recently-seen stack and hand its
    /// id to the new one.
    fn evict_and_recycle(&mut self, h: u64, stack: &[u64]) -> u32 {
        let victim = self.lru_tail;
        if victim == NO_NEXT {
            // max_entries == 0: nothing to recycle.
            self.stats.drops += 1;
            return STACK_ID_DROPPED;
        }
        let vi = victim as usize;
        if stack.len() as u32 > self.caps[vi]
            && self.frames.len() + stack.len() > u32::MAX as usize
        {
            // Arena cannot address the replacement span.
            self.stats.drops += 1;
            return STACK_ID_DROPPED;
        }
        // Unlink the victim from its hash bucket (its hash is recomputed
        // from the frames it still owns).
        let vh = hash_words(self.frames_of(victim));
        self.bucket_unlink(vh, victim);
        // Write the new frames, reusing the victim's reservation when
        // they fit.
        if stack.len() as u32 <= self.caps[vi] {
            let off = self.spans[vi].0 as usize;
            self.frames[off..off + stack.len()].copy_from_slice(stack);
            self.spans[vi] = (off as u32, stack.len() as u32);
        } else {
            let offset = self.frames.len() as u32;
            self.frames.extend_from_slice(stack);
            self.spans[vi] = (offset, stack.len() as u32);
            self.caps[vi] = stack.len() as u32;
        }
        let prev_head = self.heads.insert(h, victim).unwrap_or(NO_NEXT);
        self.chain[vi] = prev_head;
        self.lru_unlink(victim);
        self.lru_link_front(victim);
        self.stats.evictions += 1;
        self.stats.inserts += 1;
        victim
    }

    /// Remove `id` from the bucket chain whose hash is `h`.
    fn bucket_unlink(&mut self, h: u64, id: u32) {
        let Some(&head) = self.heads.get(&h) else { return };
        if head == id {
            let next = self.chain[id as usize];
            if next == NO_NEXT {
                self.heads.remove(&h);
            } else {
                self.heads.insert(h, next);
            }
            return;
        }
        let mut cur = head;
        loop {
            let next = self.chain[cur as usize];
            if next == NO_NEXT {
                return; // not in this bucket (should not happen)
            }
            if next == id {
                self.chain[cur as usize] = self.chain[id as usize];
                return;
            }
            cur = next;
        }
    }

    fn lru_link_front(&mut self, id: u32) {
        let i = id as usize;
        self.lru_prev[i] = NO_NEXT;
        self.lru_next[i] = self.lru_head;
        if self.lru_head != NO_NEXT {
            self.lru_prev[self.lru_head as usize] = id;
        }
        self.lru_head = id;
        if self.lru_tail == NO_NEXT {
            self.lru_tail = id;
        }
    }

    fn lru_unlink(&mut self, id: u32) {
        let i = id as usize;
        let p = self.lru_prev[i];
        let n = self.lru_next[i];
        if p == NO_NEXT {
            self.lru_head = n;
        } else {
            self.lru_next[p as usize] = n;
        }
        if n == NO_NEXT {
            self.lru_tail = p;
        } else {
            self.lru_prev[n as usize] = p;
        }
        self.lru_prev[i] = NO_NEXT;
        self.lru_next[i] = NO_NEXT;
    }

    fn lru_touch(&mut self, id: u32) {
        if self.lru_head == id {
            return;
        }
        self.lru_unlink(id);
        self.lru_link_front(id);
    }

    /// Resolve an id back to its frames; unknown or dropped ids resolve
    /// to the empty slice.
    #[inline]
    pub fn resolve(&self, id: u32) -> &[u64] {
        match self.spans.get(id as usize) {
            Some(&(off, len)) => &self.frames[off as usize..(off + len) as usize],
            None => &[],
        }
    }

    fn frames_of(&self, id: u32) -> &[u64] {
        let (off, len) = self.spans[id as usize];
        &self.frames[off as usize..(off + len) as usize]
    }

    /// Number of distinct stacks interned.
    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.max_entries
    }

    /// Current storage footprint: arena + spans/caps + chain + recency
    /// list + bucket index (≈32 B of `HashMap` overhead per bucket
    /// entry).
    pub fn bytes(&self) -> u64 {
        (self.frames.len() * 8
            + self.spans.len() * 8
            + self.caps.len() * 4
            + self.chain.len() * 4
            + self.lru_prev.len() * 4
            + self.lru_next.len() * 4) as u64
            + (self.heads.len() as u64) * 32
    }

    /// Static admission estimate for the verifier: what a fully-loaded
    /// map of `entries` stacks at capture depth `depth` would occupy.
    pub fn bytes_for(entries: usize, depth: usize) -> u64 {
        (entries as u64) * (depth as u64 * 8 + 44)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interns_and_resolves() {
        let mut m = StackMap::new("stacks", 16);
        let a = m.intern(&[0x100, 0x200, 0x300]);
        let b = m.intern(&[0x100, 0x200, 0x300]);
        let c = m.intern(&[0x100, 0x200]);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(m.resolve(a), &[0x100, 0x200, 0x300]);
        assert_eq!(m.resolve(c), &[0x100, 0x200]);
        assert_eq!(m.len(), 2);
        assert_eq!(m.stats.hits, 1);
        assert_eq!(m.stats.inserts, 2);
        assert_eq!(m.stats.drops, 0);
    }

    #[test]
    fn ids_are_dense_in_first_capture_order() {
        let mut m = StackMap::new("stacks", 16);
        for i in 0..5u64 {
            assert_eq!(m.intern(&[i]), i as u32);
        }
    }

    #[test]
    fn empty_stack_is_a_valid_entry() {
        let mut m = StackMap::new("stacks", 4);
        let id = m.intern(&[]);
        assert_eq!(m.resolve(id), &[] as &[u64]);
        assert_eq!(m.intern(&[]), id);
    }

    #[test]
    fn capacity_drops_new_stacks_but_keeps_old_ones() {
        let mut m = StackMap::new("stacks", 2);
        let a = m.intern(&[1]);
        let b = m.intern(&[2]);
        let d = m.intern(&[3]); // full → dropped
        assert_eq!(d, STACK_ID_DROPPED);
        assert_eq!(m.stats.drops, 1);
        // Known stacks still hit.
        assert_eq!(m.intern(&[1]), a);
        assert_eq!(m.intern(&[2]), b);
        // The sentinel resolves to nothing.
        assert_eq!(m.resolve(STACK_ID_DROPPED), &[] as &[u64]);
    }

    #[test]
    fn lru_evicts_least_recently_seen_and_recycles_id() {
        let mut m = StackMap::with_policy("stacks", 2, EvictPolicy::Lru);
        let a = m.intern(&[1, 1]);
        let b = m.intern(&[2, 2]);
        assert_eq!((a, b), (0, 1));
        // Touch A so B becomes the LRU entry, then overflow with C.
        assert_eq!(m.intern(&[1, 1]), a);
        let c = m.intern(&[3, 3]);
        assert_eq!(c, b, "C must recycle B's id");
        assert_eq!(m.resolve(c), &[3, 3]);
        assert_eq!(m.resolve(a), &[1, 1]);
        // B is gone: interning it again evicts A (now least recent).
        let b2 = m.intern(&[2, 2]);
        assert_eq!(b2, a);
        assert_eq!(m.resolve(b2), &[2, 2]);
        assert_eq!(m.stats.evictions, 2);
        assert_eq!(m.stats.drops, 0);
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn lru_recycle_handles_longer_replacement_stacks() {
        let mut m = StackMap::with_policy("stacks", 2, EvictPolicy::Lru);
        m.intern(&[1]);
        m.intern(&[2]);
        // Longer than the victim's reservation: span grows, id reused.
        let id = m.intern(&[7, 8, 9, 10]);
        assert_eq!(id, 0);
        assert_eq!(m.resolve(id), &[7, 8, 9, 10]);
        // A short stack then reuses the grown reservation in place.
        let id2 = m.intern(&[5]);
        assert_eq!(id2, 1);
        let id3 = m.intern(&[6, 6]);
        assert_eq!(id3, 0);
        assert_eq!(m.resolve(id3), &[6, 6]);
        assert_eq!(m.resolve(id2), &[5]);
    }

    #[test]
    fn lru_bucket_chains_survive_eviction() {
        // Cycle many stacks through a tiny LRU map: every survivor must
        // still resolve exactly and every re-intern must hit.
        let mut m = StackMap::with_policy("stacks", 8, EvictPolicy::Lru);
        for round in 0..50u64 {
            for i in 0..8u64 {
                let s = [round * 8 + i, round ^ i, i.wrapping_mul(0x9E37)];
                let id = m.intern(&s);
                assert_ne!(id, STACK_ID_DROPPED);
                assert_eq!(m.resolve(id), &s);
                assert_eq!(m.intern(&s), id, "immediate re-intern must hit");
            }
        }
        assert_eq!(m.len(), 8);
        assert_eq!(m.stats.drops, 0);
        assert!(m.stats.evictions > 0);
        // Arena growth is bounded by Σ per-id reservations (3 words
        // each here), not by the number of evictions.
        assert!(m.bytes() < 8 * (3 * 8 + 64) + 1024);
    }

    #[test]
    fn colliding_bucket_chains_stay_exact() {
        // Force many entries through; exactness must hold regardless of
        // how FxHash buckets them.
        let mut m = StackMap::new("stacks", 4096);
        let mut ids = Vec::new();
        for i in 0..1000u64 {
            ids.push(m.intern(&[i, i ^ 0xABCD, i.wrapping_mul(31)]));
        }
        for (i, id) in ids.iter().enumerate() {
            let i = i as u64;
            assert_eq!(m.resolve(*id), &[i, i ^ 0xABCD, i.wrapping_mul(31)]);
        }
        assert!(m.bytes() > 0);
        assert!(StackMap::bytes_for(1000, 3) >= 1000 * 24);
    }
}
