//! Verifier-lite: static admission checks for probe programs.
//!
//! The real eBPF verifier proves memory safety and bounded execution
//! before a program may attach to a live kernel — the property the paper
//! leans on in §7 ("the verifier in the eBPF framework ensures that the
//! probes are safe to attach"). Our probes are Rust, so memory safety is
//! the compiler's job; what we keep is the *resource admission* role: a
//! probe declares its static resource spec and the verifier rejects specs
//! that would be rejected (or dangerous) in a real deployment. Every GAPP
//! configuration is passed through this check before attaching.

use std::fmt;

/// Static resource declaration for a probe program.
#[derive(Clone, Debug)]
pub struct ProgramSpec {
    pub name: &'static str,
    /// Number of eBPF maps the program creates.
    pub maps: usize,
    /// Total bytes of map value storage requested up front.
    pub map_bytes: u64,
    /// Ring-buffer capacity in records.
    pub ringbuf_records: usize,
    /// Deepest stack capture requested (the paper's M).
    pub stack_depth: usize,
    /// Capacity of the stack-trace interning map in distinct stacks
    /// (`BPF_MAP_TYPE_STACK_TRACE` max_entries); 0 = no stack map.
    pub stack_map_entries: usize,
    /// Sampling period requested, if any (the paper's Δt).
    pub sample_period_ns: Option<u64>,
    /// Upper bound on instructions per handler invocation (loop-free
    /// eBPF programs have a static bound; we require the declaration).
    pub max_insns: u32,
}

/// Rejection reasons.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum VerifierError {
    TooManyMaps { got: usize, limit: usize },
    MapBytesExceeded { got: u64, limit: u64 },
    RingBufTooLarge { got: usize, limit: usize },
    StackDepthExceeded { got: usize, limit: usize },
    StackMapTooLarge { got: usize, limit: usize },
    SamplePeriodTooSmall { got: u64, floor: u64 },
    ProgramTooLong { got: u32, limit: u32 },
    ZeroInstructionProgram,
}

impl fmt::Display for VerifierError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VerifierError::TooManyMaps { got, limit } => {
                write!(f, "too many maps: {got} > {limit}")
            }
            VerifierError::MapBytesExceeded { got, limit } => {
                write!(f, "map storage {got} B exceeds {limit} B")
            }
            VerifierError::RingBufTooLarge { got, limit } => {
                write!(f, "ring buffer {got} records exceeds {limit}")
            }
            VerifierError::StackDepthExceeded { got, limit } => {
                write!(f, "stack capture depth {got} exceeds {limit}")
            }
            VerifierError::StackMapTooLarge { got, limit } => {
                write!(f, "stack map capacity {got} entries exceeds {limit}")
            }
            VerifierError::SamplePeriodTooSmall { got, floor } => {
                write!(f, "sampling period {got} ns below floor {floor} ns")
            }
            VerifierError::ProgramTooLong { got, limit } => {
                write!(f, "program length {got} insns exceeds {limit}")
            }
            VerifierError::ZeroInstructionProgram => {
                write!(f, "empty probe program")
            }
        }
    }
}

impl std::error::Error for VerifierError {}

/// Admission limits (defaults mirror kernel-era eBPF constants where one
/// exists: 1M instructions, 127-frame stack captures).
#[derive(Clone, Debug)]
pub struct Verifier {
    pub max_maps: usize,
    pub max_map_bytes: u64,
    pub max_ringbuf_records: usize,
    pub max_stack_depth: usize,
    /// Cap on stack-map capacity (distinct interned stacks).
    pub max_stack_map_entries: usize,
    /// Floor on Δt: sampling faster than this would dominate runtime.
    pub min_sample_period_ns: u64,
    pub max_insns: u32,
}

impl Default for Verifier {
    fn default() -> Self {
        Verifier {
            max_maps: 64,
            max_map_bytes: 1 << 30,       // 1 GB of map storage
            max_ringbuf_records: 1 << 24, // 16M records
            max_stack_depth: 127,         // PERF_MAX_STACK_DEPTH
            max_stack_map_entries: 1 << 20, // 1M distinct stacks
            min_sample_period_ns: 10_000, // 10 µs
            max_insns: 1_000_000,         // BPF_COMPLEXITY_LIMIT_INSNS
        }
    }
}

impl Verifier {
    /// Check a program spec; `Ok(())` admits it for attachment.
    pub fn check(&self, spec: &ProgramSpec) -> Result<(), VerifierError> {
        if spec.max_insns == 0 {
            return Err(VerifierError::ZeroInstructionProgram);
        }
        if spec.maps > self.max_maps {
            return Err(VerifierError::TooManyMaps {
                got: spec.maps,
                limit: self.max_maps,
            });
        }
        if spec.map_bytes > self.max_map_bytes {
            return Err(VerifierError::MapBytesExceeded {
                got: spec.map_bytes,
                limit: self.max_map_bytes,
            });
        }
        if spec.ringbuf_records > self.max_ringbuf_records {
            return Err(VerifierError::RingBufTooLarge {
                got: spec.ringbuf_records,
                limit: self.max_ringbuf_records,
            });
        }
        if spec.stack_depth > self.max_stack_depth {
            return Err(VerifierError::StackDepthExceeded {
                got: spec.stack_depth,
                limit: self.max_stack_depth,
            });
        }
        if spec.stack_map_entries > self.max_stack_map_entries {
            return Err(VerifierError::StackMapTooLarge {
                got: spec.stack_map_entries,
                limit: self.max_stack_map_entries,
            });
        }
        if let Some(p) = spec.sample_period_ns {
            if p < self.min_sample_period_ns {
                return Err(VerifierError::SamplePeriodTooSmall {
                    got: p,
                    floor: self.min_sample_period_ns,
                });
            }
        }
        if spec.max_insns > self.max_insns {
            return Err(VerifierError::ProgramTooLong {
                got: spec.max_insns,
                limit: self.max_insns,
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ok_spec() -> ProgramSpec {
        ProgramSpec {
            name: "gapp",
            maps: 7,
            map_bytes: 1 << 20,
            ringbuf_records: 1 << 16,
            stack_depth: 16,
            stack_map_entries: 1 << 14,
            sample_period_ns: Some(3_000_000),
            max_insns: 4096,
        }
    }

    #[test]
    fn admits_gapp_like_spec() {
        assert!(Verifier::default().check(&ok_spec()).is_ok());
    }

    #[test]
    fn rejects_deep_stacks() {
        let mut s = ok_spec();
        s.stack_depth = 500;
        let e = Verifier::default().check(&s).unwrap_err();
        assert!(matches!(e, VerifierError::StackDepthExceeded { .. }));
    }

    #[test]
    fn rejects_hot_sampler() {
        let mut s = ok_spec();
        s.sample_period_ns = Some(100);
        let e = Verifier::default().check(&s).unwrap_err();
        assert!(matches!(e, VerifierError::SamplePeriodTooSmall { .. }));
        assert!(e.to_string().contains("sampling period"));
    }

    #[test]
    fn rejects_oversized_stack_map() {
        let mut s = ok_spec();
        s.stack_map_entries = 1 << 22;
        let e = Verifier::default().check(&s).unwrap_err();
        assert!(matches!(e, VerifierError::StackMapTooLarge { .. }));
        assert!(e.to_string().contains("stack map"));
    }

    #[test]
    fn rejects_monster_maps() {
        let mut s = ok_spec();
        s.map_bytes = 1 << 40;
        assert!(matches!(
            Verifier::default().check(&s),
            Err(VerifierError::MapBytesExceeded { .. })
        ));
    }

    #[test]
    fn rejects_empty_program() {
        let mut s = ok_spec();
        s.max_insns = 0;
        assert_eq!(
            Verifier::default().check(&s),
            Err(VerifierError::ZeroInstructionProgram)
        );
    }

    #[test]
    fn no_sampler_is_fine() {
        let mut s = ok_spec();
        s.sample_period_ns = None;
        assert!(Verifier::default().check(&s).is_ok());
    }
}
