//! eBPF map analogues: global hash maps, global scalars, per-CPU scalars.
//!
//! These back the Table-1 map set (`cm_hash`, `global_cm`, `local_cm`,
//! `thread_count`, `total_count`, `thread_list`, `t_switch`). They track
//! their own byte footprint so the profiler can report the paper's memory
//! column (M) from mechanism rather than guesswork.

use crate::util::fxhash::FxHashMap;

/// A BPF_MAP_TYPE_HASH with u64 keys and values.
///
/// Backed by an [`FxHashMap`]: the kernel's htab uses a cheap jhash, not
/// a keyed SipHash, and these maps sit on the per-event probe hot path
/// (`thread_list` is consulted on every sched_switch).
#[derive(Debug, Default)]
pub struct HashMap64 {
    name: &'static str,
    inner: FxHashMap<u64, u64>,
    /// High-water mark of entries, for memory accounting.
    peak: usize,
}

impl HashMap64 {
    pub fn new(name: &'static str) -> HashMap64 {
        HashMap64 {
            name,
            inner: FxHashMap::default(),
            peak: 0,
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    #[inline]
    pub fn get(&self, k: u64) -> Option<u64> {
        self.inner.get(&k).copied()
    }

    #[inline]
    pub fn insert(&mut self, k: u64, v: u64) {
        self.inner.insert(k, v);
        self.peak = self.peak.max(self.inner.len());
    }

    /// `map[k] += delta` (missing key starts at 0), BPF-style.
    #[inline]
    pub fn add(&mut self, k: u64, delta: u64) {
        *self.inner.entry(k).or_insert(0) += delta;
        self.peak = self.peak.max(self.inner.len());
    }

    #[inline]
    pub fn remove(&mut self, k: u64) -> Option<u64> {
        self.inner.remove(&k)
    }

    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        self.inner.iter().map(|(k, v)| (*k, *v))
    }

    /// Peak memory estimate: key + value + bucket overhead per entry
    /// (matches the 32-byte htab element the kernel allocates for 8/8).
    pub fn peak_bytes(&self) -> u64 {
        (self.peak as u64) * 32
    }
}

/// A global scalar (BPF_MAP_TYPE_ARRAY of size 1).
#[derive(Debug, Default, Clone, Copy)]
pub struct Scalar {
    v: u64,
}

impl Scalar {
    #[inline]
    pub fn get(&self) -> u64 {
        self.v
    }

    #[inline]
    pub fn set(&mut self, v: u64) {
        self.v = v;
    }

    #[inline]
    pub fn add(&mut self, d: u64) {
        self.v += d;
    }

    #[inline]
    pub fn sub_sat(&mut self, d: u64) {
        self.v = self.v.saturating_sub(d);
    }
}

/// A per-CPU scalar (BPF_MAP_TYPE_PERCPU_ARRAY of size 1): each CPU reads
/// and writes its own slot without synchronization, exactly how GAPP's
/// `local_cm` and `t_switch` avoid cross-core contention.
#[derive(Debug)]
pub struct PerCpuScalar {
    slots: Vec<u64>,
}

impl PerCpuScalar {
    pub fn new(ncpu: usize) -> PerCpuScalar {
        PerCpuScalar {
            slots: vec![0; ncpu],
        }
    }

    #[inline]
    pub fn get(&self, cpu: usize) -> u64 {
        self.slots[cpu]
    }

    #[inline]
    pub fn set(&mut self, cpu: usize, v: u64) {
        self.slots[cpu] = v;
    }

    pub fn bytes(&self) -> u64 {
        (self.slots.len() * 8) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_basic_ops() {
        let mut m = HashMap64::new("cm_hash");
        assert!(m.get(5).is_none());
        m.insert(5, 100);
        assert_eq!(m.get(5), Some(100));
        m.add(5, 20);
        assert_eq!(m.get(5), Some(120));
        m.add(9, 7);
        assert_eq!(m.get(9), Some(7));
        assert_eq!(m.remove(5), Some(120));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn hash_peak_accounting() {
        let mut m = HashMap64::new("thread_list");
        for i in 0..100 {
            m.insert(i, 1);
        }
        for i in 0..50 {
            m.remove(i);
        }
        assert_eq!(m.len(), 50);
        assert_eq!(m.peak_bytes(), 100 * 32);
    }

    #[test]
    fn scalar_ops() {
        let mut s = Scalar::default();
        s.add(5);
        s.sub_sat(2);
        assert_eq!(s.get(), 3);
        s.sub_sat(10);
        assert_eq!(s.get(), 0); // never negative, like the paper's counters
    }

    #[test]
    fn per_cpu_independent() {
        let mut p = PerCpuScalar::new(4);
        p.set(0, 10);
        p.set(3, 30);
        assert_eq!(p.get(0), 10);
        assert_eq!(p.get(1), 0);
        assert_eq!(p.get(3), 30);
        assert_eq!(p.bytes(), 32);
    }
}
