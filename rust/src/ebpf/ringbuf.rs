//! The circular buffer between kernel probes and the user-space probe
//! (paper Figure 2). Bounded like a perf ring buffer: when the consumer
//! falls behind, new records are *dropped* and counted, which is exactly
//! the failure mode a real deployment tunes buffer pages against.
//!
//! Two transports are provided:
//!
//! * [`RingBuf`] — one bounded FIFO (the `BPF_MAP_TYPE_RINGBUF` shape).
//! * [`ShardedRing`] — one [`RingBuf`] per CPU, the `PERF_EVENT_ARRAY`
//!   shape GAPP's real deployment reads from. Producers push to the
//!   shard of the CPU the event fired on (preserving per-CPU FIFO
//!   order); consumers re-establish the global order from the records'
//!   capture timestamps via [`ShardedRing::pop_global`].
//!
//! Epoch-based consumers (the streaming analyzer's poll loop) read the
//! producer counters through a [`RingCursor`], which attributes pushes,
//! drains and — crucially — *drops* to the epoch in which they occurred
//! instead of one run-global total.

/// Drop/throughput statistics for a ring buffer.
#[derive(Clone, Copy, Debug, Default)]
pub struct RingBufStats {
    pub pushed: u64,
    pub dropped: u64,
    pub drained: u64,
    /// High-water mark of queued records.
    pub peak: usize,
}

impl RingBufStats {
    /// Fold another ring's counters into this one (multi-ring
    /// aggregation). `peak` sums: the shards buffer independently, so
    /// the summed high-water marks bound the combined footprint.
    pub fn absorb(&mut self, o: &RingBufStats) {
        self.pushed += o.pushed;
        self.dropped += o.dropped;
        self.drained += o.drained;
        self.peak += o.peak;
    }
}

/// Producer-side activity observed by a [`RingCursor`] over one epoch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EpochDelta {
    /// Records successfully pushed during the epoch.
    pub pushed: u64,
    /// Records dropped at capacity during the epoch — the per-window
    /// drop figure the streaming report surfaces.
    pub dropped: u64,
    /// Records drained by consumers during the epoch.
    pub drained: u64,
}

impl EpochDelta {
    /// Sum another shard's epoch activity into this one.
    pub fn absorb(&mut self, o: &EpochDelta) {
        self.pushed += o.pushed;
        self.dropped += o.dropped;
        self.drained += o.drained;
    }
}

/// Consumer cursor: a snapshot of a ring buffer's monotonic counters.
///
/// An epoch-windowed consumer calls [`RingCursor::advance`] once per
/// epoch; the returned [`EpochDelta`] charges exactly the activity since
/// the previous call, so drops land in the window where they happened
/// (previously only a single run-global counter existed).
#[derive(Clone, Copy, Debug, Default)]
pub struct RingCursor {
    pushed_seen: u64,
    dropped_seen: u64,
    drained_seen: u64,
}

impl RingCursor {
    /// Advance to `rb`'s current counters, returning the deltas since
    /// this cursor last observed them.
    pub fn advance<T>(&mut self, rb: &RingBuf<T>) -> EpochDelta {
        let d = EpochDelta {
            pushed: rb.stats.pushed - self.pushed_seen,
            dropped: rb.stats.dropped - self.dropped_seen,
            drained: rb.stats.drained - self.drained_seen,
        };
        self.pushed_seen = rb.stats.pushed;
        self.dropped_seen = rb.stats.dropped;
        self.drained_seen = rb.stats.drained;
        d
    }
}

/// Bounded FIFO of records of type `T`.
#[derive(Debug)]
pub struct RingBuf<T> {
    buf: std::collections::VecDeque<T>,
    capacity: usize,
    pub stats: RingBufStats,
    /// Approximate bytes per record, for memory accounting.
    record_bytes: u64,
}

impl<T> RingBuf<T> {
    pub fn new(capacity: usize) -> RingBuf<T> {
        RingBuf::with_reserve(capacity, capacity.min(1 << 16))
    }

    /// A ring with an explicit initial backing reservation (sharded
    /// transports split one reservation budget across many rings).
    /// A zero-capacity ring would silently drop every record, so it is
    /// rejected here; user-facing knobs reject it earlier with a real
    /// error (`GappConfig::validate`).
    pub fn with_reserve(capacity: usize, reserve: usize) -> RingBuf<T> {
        assert!(capacity >= 1, "ring capacity must be >= 1");
        RingBuf {
            buf: std::collections::VecDeque::with_capacity(reserve.min(capacity)),
            capacity,
            stats: RingBufStats::default(),
            record_bytes: std::mem::size_of::<T>() as u64,
        }
    }

    /// Push a record; returns false (and counts a drop) when full.
    #[inline]
    pub fn push(&mut self, rec: T) -> bool {
        if self.buf.len() >= self.capacity {
            self.stats.dropped += 1;
            return false;
        }
        self.buf.push_back(rec);
        self.stats.pushed += 1;
        self.stats.peak = self.stats.peak.max(self.buf.len());
        true
    }

    /// Pop the oldest record.
    #[inline]
    pub fn pop(&mut self) -> Option<T> {
        let r = self.buf.pop_front();
        if r.is_some() {
            self.stats.drained += 1;
        }
        r
    }

    /// The oldest buffered record without consuming it (what a merging
    /// multi-ring consumer compares timestamps on).
    #[inline]
    pub fn peek(&self) -> Option<&T> {
        self.buf.front()
    }

    /// Drain up to `max` records into `out` (reuses the caller's vector —
    /// the hot path never allocates).
    pub fn drain_into(&mut self, max: usize, out: &mut Vec<T>) -> usize {
        let n = max.min(self.buf.len());
        for _ in 0..n {
            out.push(self.buf.pop_front().unwrap());
        }
        self.stats.drained += n as u64;
        n
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Peak memory footprint estimate.
    pub fn peak_bytes(&self) -> u64 {
        self.stats.peak as u64 * self.record_bytes
    }

    /// A cursor positioned at the buffer's *current* counters (an epoch
    /// starting now). Use `RingCursor::default()` for a cursor that
    /// charges everything since buffer creation to its first epoch.
    pub fn cursor(&self) -> RingCursor {
        RingCursor {
            pushed_seen: self.stats.pushed,
            dropped_seen: self.stats.dropped,
            drained_seen: self.stats.drained,
        }
    }
}

/// A record carried by a sharded ring, with its capture timestamp.
///
/// `t` is the simulated time the producing tracepoint fired; `seq` is a
/// strictly monotone global capture sequence — the sub-nanosecond
/// tiebreak a real monotonic clock provides for free, and what lets a
/// consumer merge shard FIFOs back into the exact production order.
#[derive(Clone, Copy, Debug)]
pub struct Stamped<T> {
    pub t: u64,
    pub seq: u64,
    pub rec: T,
}

/// One bounded ring per CPU — the `PERF_EVENT_ARRAY` transport shape.
///
/// Producers route each record to the shard of the CPU the event fired
/// on (`cpu % shards`), so every shard is a per-CPU FIFO exactly like a
/// real perf buffer page set. Capacity is *per shard*, matching how
/// perf buffer pages are sized per CPU. Consumers either walk shards
/// individually (per-shard cursors) or call [`ShardedRing::pop_global`]
/// to re-establish the global order from the `(t, seq)` stamps.
#[derive(Debug)]
pub struct ShardedRing<T> {
    shards: Vec<RingBuf<Stamped<T>>>,
    seq: u64,
}

impl<T> ShardedRing<T> {
    /// `nshards` rings of `capacity` records each. The initial backing
    /// reservation is split across shards so a many-shard transport
    /// pre-allocates no more than a single ring used to.
    pub fn new(nshards: usize, capacity: usize) -> ShardedRing<T> {
        assert!(nshards >= 1, "sharded ring needs at least one shard");
        let reserve = ((1 << 16) / nshards).max(64);
        ShardedRing {
            shards: (0..nshards)
                .map(|_| RingBuf::with_reserve(capacity, reserve))
                .collect(),
            seq: 0,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Per-shard capacity (records).
    pub fn capacity(&self) -> usize {
        self.shards[0].capacity()
    }

    /// Read access to one shard (per-shard cursors, stats, tests).
    pub fn shard(&self, i: usize) -> &RingBuf<Stamped<T>> {
        &self.shards[i]
    }

    /// Push a record captured on `cpu` at time `t`; returns false (and
    /// counts a drop on the owning shard) when that shard is full.
    #[inline]
    pub fn push(&mut self, cpu: usize, t: u64, rec: T) -> bool {
        self.seq += 1;
        let i = cpu % self.shards.len();
        self.shards[i].push(Stamped { t, seq: self.seq, rec })
    }

    /// Pop the globally-oldest buffered record: the minimum `(t, seq)`
    /// stamp across all shard heads. Because `seq` is globally monotone,
    /// draining to empty replays records exactly in production order —
    /// the property the sharded-vs-single-ring golden tests pin down.
    pub fn pop_global_stamped(&mut self) -> Option<Stamped<T>> {
        // One shard: per-shard FIFO order *is* the global order — skip
        // the cross-shard head scan (the `--shards 1` batch path used
        // to pay it on every single pop).
        if self.shards.len() == 1 {
            return self.shards[0].pop();
        }
        let mut best: Option<(usize, (u64, u64))> = None;
        for (i, s) in self.shards.iter().enumerate() {
            if let Some(head) = s.peek() {
                let key = (head.t, head.seq);
                if best.map_or(true, |(_, bk)| key < bk) {
                    best = Some((i, key));
                }
            }
        }
        best.and_then(|(i, _)| self.shards[i].pop())
    }

    /// [`ShardedRing::pop_global_stamped`], unwrapped to the record.
    /// Linear in the shard count per pop — fine for tests and small
    /// drains; bulk consumers use [`ShardedRing::drain_global`].
    #[inline]
    pub fn pop_global(&mut self) -> Option<T> {
        self.pop_global_stamped().map(|s| s.rec)
    }

    /// Drain *everything* buffered, invoking `f` on each record in
    /// global `(t, seq)` order: a k-way merge over the shard heads,
    /// O(records · log shards) instead of pop_global's
    /// O(records · shards). The tiny head-heap (≤ shards entries) is
    /// the only allocation, amortized over the whole drain.
    pub fn drain_global(&mut self, mut f: impl FnMut(T)) {
        // One shard: the FIFO already is the global stream — no heap.
        if self.shards.len() == 1 {
            while let Some(s) = self.shards[0].pop() {
                f(s.rec);
            }
            return;
        }
        use std::cmp::Reverse;
        let mut heads: std::collections::BinaryHeap<Reverse<(u64, u64, usize)>> =
            std::collections::BinaryHeap::with_capacity(self.shards.len());
        for (i, s) in self.shards.iter().enumerate() {
            if let Some(h) = s.peek() {
                heads.push(Reverse((h.t, h.seq, i)));
            }
        }
        while let Some(Reverse((_, _, i))) = heads.pop() {
            let rec = self.shards[i].pop().expect("head tracked a nonempty shard");
            f(rec.rec);
            if let Some(h) = self.shards[i].peek() {
                heads.push(Reverse((h.t, h.seq, i)));
            }
        }
    }

    /// Drain *one shard* to empty, invoking `f` on each stamped record
    /// in that shard's FIFO (= capture) order. No cross-shard ordering
    /// is established — this is the shard-local fold path of the merge
    /// tree (`MergeStrategy::Tree`), where each shard's consumer folds
    /// its own stream and only the order-sensitive record subset is
    /// re-merged globally at window close.
    pub fn drain_shard(&mut self, i: usize, mut f: impl FnMut(Stamped<T>)) {
        while let Some(s) = self.shards[i].pop() {
            f(s);
        }
    }

    /// Drain *one shard* to empty into `out`, preserving shard FIFO
    /// order and reusing the caller's buffer. This is the hand-off shape
    /// of the threaded lane path (`--lane-threads N`): the driver drains
    /// each shard into a recycled batch and sends the whole batch to the
    /// shard's lane worker — one message per (epoch × shard), not one
    /// per record.
    pub fn drain_shard_into(&mut self, i: usize, out: &mut Vec<Stamped<T>>) {
        while let Some(s) = self.shards[i].pop() {
            out.push(s);
        }
    }

    /// Total records currently buffered across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.is_empty())
    }

    /// True when any shard has reached `threshold` records — the
    /// per-shard drain watermark (each CPU's buffer signals its reader
    /// independently in a real perf setup). O(shards): use
    /// [`ShardedRing::len_for_cpu`] on the hot path, where the CPU that
    /// just pushed is known.
    pub fn any_at_or_above(&self, threshold: usize) -> bool {
        self.shards.iter().any(|s| s.len() >= threshold)
    }

    /// Buffered records on the shard owning `cpu` — the O(1) watermark
    /// probe for the event hot path (only the shard an event pushed to
    /// can have grown since it was last checked).
    #[inline]
    pub fn len_for_cpu(&self, cpu: usize) -> usize {
        self.shards[cpu % self.shards.len()].len()
    }

    /// Counters aggregated across shards.
    pub fn stats(&self) -> RingBufStats {
        let mut agg = RingBufStats::default();
        for s in &self.shards {
            agg.absorb(&s.stats);
        }
        agg
    }

    /// Per-shard counters, indexed by shard (the report's breakdown).
    pub fn shard_stats(&self) -> Vec<RingBufStats> {
        self.shards.iter().map(|s| s.stats).collect()
    }

    /// Peak memory footprint estimate, summed over shards.
    pub fn peak_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.peak_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut rb = RingBuf::new(8);
        for i in 0..5 {
            assert!(rb.push(i));
        }
        for i in 0..5 {
            assert_eq!(rb.pop(), Some(i));
        }
        assert!(rb.pop().is_none());
    }

    #[test]
    fn overflow_drops_and_counts() {
        let mut rb = RingBuf::new(3);
        for i in 0..5 {
            rb.push(i);
        }
        assert_eq!(rb.len(), 3);
        assert_eq!(rb.stats.dropped, 2);
        assert_eq!(rb.pop(), Some(0)); // oldest survives; new arrivals dropped
    }

    #[test]
    fn drain_into_reuses_vec() {
        let mut rb = RingBuf::new(16);
        for i in 0..10 {
            rb.push(i);
        }
        let mut out = Vec::with_capacity(16);
        let n = rb.drain_into(4, &mut out);
        assert_eq!(n, 4);
        assert_eq!(out, vec![0, 1, 2, 3]);
        let n2 = rb.drain_into(100, &mut out);
        assert_eq!(n2, 6);
        assert_eq!(rb.len(), 0);
        assert_eq!(rb.stats.drained, 10);
    }

    #[test]
    fn cursor_attributes_drops_to_their_epoch() {
        let mut rb = RingBuf::new(4);
        let mut cur = RingCursor::default();
        // Epoch 1: 6 pushes into a 4-slot ring → 2 drops.
        for i in 0..6 {
            rb.push(i);
        }
        let e1 = cur.advance(&rb);
        assert_eq!(e1.pushed, 4);
        assert_eq!(e1.dropped, 2);
        assert_eq!(e1.drained, 0);
        // Consumer catches up, then epoch 2 overflows by exactly 1.
        while rb.pop().is_some() {}
        for i in 0..5 {
            rb.push(i);
        }
        let e2 = cur.advance(&rb);
        assert_eq!(e2.pushed, 4);
        assert_eq!(e2.dropped, 1);
        assert_eq!(e2.drained, 4);
        // Per-epoch drops sum to the global counter.
        assert_eq!(e1.dropped + e2.dropped, rb.stats.dropped);
        // A quiet epoch reports all-zero deltas.
        assert_eq!(cur.advance(&rb), EpochDelta::default());
    }

    #[test]
    fn fresh_cursor_starts_at_current_counters() {
        let mut rb = RingBuf::new(2);
        for i in 0..5 {
            rb.push(i);
        }
        // `cursor()` skips history; `default()` charges it to epoch 1.
        let mut at_now = rb.cursor();
        let mut from_start = RingCursor::default();
        rb.push(9);
        assert_eq!(at_now.advance(&rb).dropped, 1);
        assert_eq!(from_start.advance(&rb).dropped, 4);
    }

    #[test]
    fn peak_tracking() {
        let mut rb = RingBuf::new(100);
        for i in 0..50 {
            rb.push(i);
        }
        for _ in 0..50 {
            rb.pop();
        }
        assert_eq!(rb.stats.peak, 50);
        assert!(rb.peak_bytes() >= 50 * 4);
    }

    #[test]
    #[should_panic(expected = "capacity must be >= 1")]
    fn zero_capacity_ring_is_rejected() {
        let _ = RingBuf::<u32>::new(0);
    }

    #[test]
    #[should_panic(expected = "at least one shard")]
    fn zero_shard_ring_is_rejected() {
        let _ = ShardedRing::<u32>::new(0, 8);
    }

    #[test]
    fn sharded_preserves_per_cpu_fifo_and_global_order() {
        let mut sr: ShardedRing<u32> = ShardedRing::new(3, 8);
        // Interleave pushes across CPUs, some at the same timestamp —
        // the global pop order must equal production order.
        let plan = [(0usize, 10u64), (2, 10), (1, 11), (0, 12), (2, 12), (2, 13)];
        for (i, (cpu, t)) in plan.iter().enumerate() {
            assert!(sr.push(*cpu, *t, i as u32));
        }
        assert_eq!(sr.len(), 6);
        // Per-shard FIFO: shard 2 holds records 1, 4, 5 in push order.
        assert_eq!(sr.shard(2).len(), 3);
        assert_eq!(sr.shard(2).peek().unwrap().rec, 1);
        let mut order = Vec::new();
        while let Some(r) = sr.pop_global() {
            order.push(r);
        }
        assert_eq!(order, vec![0, 1, 2, 3, 4, 5]);
        assert!(sr.is_empty());
    }

    #[test]
    fn drain_global_matches_pop_global_order() {
        let fill = |sr: &mut ShardedRing<u32>| {
            for i in 0..30u64 {
                sr.push((i % 5) as usize, i / 3, i as u32);
            }
        };
        let mut a: ShardedRing<u32> = ShardedRing::new(5, 16);
        let mut b: ShardedRing<u32> = ShardedRing::new(5, 16);
        fill(&mut a);
        fill(&mut b);
        let mut via_pop = Vec::new();
        while let Some(r) = a.pop_global() {
            via_pop.push(r);
        }
        let mut via_drain = Vec::new();
        b.drain_global(|r| via_drain.push(r));
        assert_eq!(via_pop, via_drain);
        assert_eq!(via_drain, (0..30).collect::<Vec<u32>>());
        assert!(b.is_empty());
        assert_eq!(b.stats().drained, 30);
        // O(1) per-CPU watermark probe agrees with the shard lengths.
        b.push(7, 99, 1234); // cpu 7 → shard 2
        assert_eq!(b.len_for_cpu(7), 1);
        assert_eq!(b.len_for_cpu(0), 0);
    }

    #[test]
    fn single_shard_fast_path_matches_the_general_drain() {
        // `--shards 1` skips the head scan / merge heap entirely; the
        // observable behaviour (order, stats) must be unchanged.
        let fill = |sr: &mut ShardedRing<u32>| {
            for i in 0..20u64 {
                sr.push(0, i / 2, i as u32);
            }
        };
        let mut a: ShardedRing<u32> = ShardedRing::new(1, 32);
        fill(&mut a);
        let mut popped = Vec::new();
        while let Some(r) = a.pop_global() {
            popped.push(r);
        }
        assert_eq!(popped, (0..20).collect::<Vec<u32>>());
        let mut b: ShardedRing<u32> = ShardedRing::new(1, 32);
        fill(&mut b);
        let mut drained = Vec::new();
        b.drain_global(|r| drained.push(r));
        assert_eq!(drained, popped);
        assert_eq!(b.stats().drained, 20);
        assert!(b.is_empty());
    }

    #[test]
    fn drain_shard_preserves_fifo_and_counts_drained() {
        let mut sr: ShardedRing<u32> = ShardedRing::new(3, 8);
        // Shard 1 (cpu 1) receives 2, then 0; shard 0 receives 1.
        sr.push(1, 10, 2);
        sr.push(0, 11, 1);
        sr.push(1, 12, 0);
        let mut seen = Vec::new();
        sr.drain_shard(1, |s| seen.push((s.t, s.rec)));
        // Shard order, not global order — and the stamps ride along.
        assert_eq!(seen, vec![(10, 2), (12, 0)]);
        assert_eq!(sr.shard(1).stats.drained, 2);
        assert_eq!(sr.shard(0).len(), 1, "other shards untouched");
        sr.drain_shard(0, |_| {});
        assert!(sr.is_empty());
    }

    #[test]
    fn drain_shard_into_reuses_the_buffer_and_matches_drain_shard() {
        let fill = |sr: &mut ShardedRing<u32>| {
            for i in 0..12u64 {
                sr.push((i % 2) as usize, i, i as u32);
            }
        };
        let mut a: ShardedRing<u32> = ShardedRing::new(2, 16);
        let mut b: ShardedRing<u32> = ShardedRing::new(2, 16);
        fill(&mut a);
        fill(&mut b);
        let mut via_cb = Vec::new();
        a.drain_shard(1, |s| via_cb.push((s.t, s.seq, s.rec)));
        let mut buf: Vec<Stamped<u32>> = Vec::with_capacity(8);
        b.drain_shard_into(1, &mut buf);
        let via_buf: Vec<_> = buf.iter().map(|s| (s.t, s.seq, s.rec)).collect();
        assert_eq!(via_cb, via_buf);
        assert_eq!(b.shard(1).stats.drained, 6);
        assert_eq!(b.shard(0).len(), 6, "other shards untouched");
        // Recycled buffer: a second drain appends after clear.
        buf.clear();
        b.drain_shard_into(0, &mut buf);
        assert_eq!(buf.len(), 6);
        assert!(b.is_empty());
    }

    #[test]
    fn sharded_drops_count_on_the_owning_shard() {
        let mut sr: ShardedRing<u32> = ShardedRing::new(2, 2);
        // CPU 0 overflows its shard; CPU 1 stays within capacity.
        for i in 0..5 {
            sr.push(0, i, i as u32);
        }
        sr.push(1, 9, 99);
        let per = sr.shard_stats();
        assert_eq!(per[0].dropped, 3);
        assert_eq!(per[1].dropped, 0);
        let agg = sr.stats();
        assert_eq!(agg.pushed, 3);
        assert_eq!(agg.dropped, 3);
        assert_eq!(agg.peak, 3); // 2 on shard 0 + 1 on shard 1
        // The watermark is per shard, not total.
        assert!(sr.any_at_or_above(2));
        assert!(!sr.any_at_or_above(3));
    }

    #[test]
    fn sharded_cursors_attribute_per_shard_epochs() {
        let mut sr: ShardedRing<u32> = ShardedRing::new(2, 2);
        let mut cursors = [RingCursor::default(), RingCursor::default()];
        for i in 0..4 {
            sr.push(0, i, i as u32); // 2 pushed, 2 dropped on shard 0
        }
        sr.push(1, 9, 9);
        while sr.pop_global().is_some() {}
        let d0 = cursors[0].advance(sr.shard(0));
        let d1 = cursors[1].advance(sr.shard(1));
        assert_eq!((d0.pushed, d0.dropped, d0.drained), (2, 2, 2));
        assert_eq!((d1.pushed, d1.dropped, d1.drained), (1, 0, 1));
        let mut total = EpochDelta::default();
        total.absorb(&d0);
        total.absorb(&d1);
        assert_eq!(total.dropped, sr.stats().dropped);
    }
}
