//! The circular buffer between kernel probes and the user-space probe
//! (paper Figure 2). Bounded like a perf ring buffer: when the consumer
//! falls behind, new records are *dropped* and counted, which is exactly
//! the failure mode a real deployment tunes buffer pages against.
//!
//! Epoch-based consumers (the streaming analyzer's poll loop) read the
//! producer counters through a [`RingCursor`], which attributes pushes,
//! drains and — crucially — *drops* to the epoch in which they occurred
//! instead of one run-global total.

/// Drop/throughput statistics for a ring buffer.
#[derive(Clone, Copy, Debug, Default)]
pub struct RingBufStats {
    pub pushed: u64,
    pub dropped: u64,
    pub drained: u64,
    /// High-water mark of queued records.
    pub peak: usize,
}

/// Producer-side activity observed by a [`RingCursor`] over one epoch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EpochDelta {
    /// Records successfully pushed during the epoch.
    pub pushed: u64,
    /// Records dropped at capacity during the epoch — the per-window
    /// drop figure the streaming report surfaces.
    pub dropped: u64,
    /// Records drained by consumers during the epoch.
    pub drained: u64,
}

/// Consumer cursor: a snapshot of a ring buffer's monotonic counters.
///
/// An epoch-windowed consumer calls [`RingCursor::advance`] once per
/// epoch; the returned [`EpochDelta`] charges exactly the activity since
/// the previous call, so drops land in the window where they happened
/// (previously only a single run-global counter existed).
#[derive(Clone, Copy, Debug, Default)]
pub struct RingCursor {
    pushed_seen: u64,
    dropped_seen: u64,
    drained_seen: u64,
}

impl RingCursor {
    /// Advance to `rb`'s current counters, returning the deltas since
    /// this cursor last observed them.
    pub fn advance<T>(&mut self, rb: &RingBuf<T>) -> EpochDelta {
        let d = EpochDelta {
            pushed: rb.stats.pushed - self.pushed_seen,
            dropped: rb.stats.dropped - self.dropped_seen,
            drained: rb.stats.drained - self.drained_seen,
        };
        self.pushed_seen = rb.stats.pushed;
        self.dropped_seen = rb.stats.dropped;
        self.drained_seen = rb.stats.drained;
        d
    }
}

/// Bounded FIFO of records of type `T`.
#[derive(Debug)]
pub struct RingBuf<T> {
    buf: std::collections::VecDeque<T>,
    capacity: usize,
    pub stats: RingBufStats,
    /// Approximate bytes per record, for memory accounting.
    record_bytes: u64,
}

impl<T> RingBuf<T> {
    pub fn new(capacity: usize) -> RingBuf<T> {
        RingBuf {
            buf: std::collections::VecDeque::with_capacity(capacity.min(1 << 16)),
            capacity,
            stats: RingBufStats::default(),
            record_bytes: std::mem::size_of::<T>() as u64,
        }
    }

    /// Push a record; returns false (and counts a drop) when full.
    #[inline]
    pub fn push(&mut self, rec: T) -> bool {
        if self.buf.len() >= self.capacity {
            self.stats.dropped += 1;
            return false;
        }
        self.buf.push_back(rec);
        self.stats.pushed += 1;
        self.stats.peak = self.stats.peak.max(self.buf.len());
        true
    }

    /// Pop the oldest record.
    #[inline]
    pub fn pop(&mut self) -> Option<T> {
        let r = self.buf.pop_front();
        if r.is_some() {
            self.stats.drained += 1;
        }
        r
    }

    /// Drain up to `max` records into `out` (reuses the caller's vector —
    /// the hot path never allocates).
    pub fn drain_into(&mut self, max: usize, out: &mut Vec<T>) -> usize {
        let n = max.min(self.buf.len());
        for _ in 0..n {
            out.push(self.buf.pop_front().unwrap());
        }
        self.stats.drained += n as u64;
        n
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Peak memory footprint estimate.
    pub fn peak_bytes(&self) -> u64 {
        self.stats.peak as u64 * self.record_bytes
    }

    /// A cursor positioned at the buffer's *current* counters (an epoch
    /// starting now). Use `RingCursor::default()` for a cursor that
    /// charges everything since buffer creation to its first epoch.
    pub fn cursor(&self) -> RingCursor {
        RingCursor {
            pushed_seen: self.stats.pushed,
            dropped_seen: self.stats.dropped,
            drained_seen: self.stats.drained,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut rb = RingBuf::new(8);
        for i in 0..5 {
            assert!(rb.push(i));
        }
        for i in 0..5 {
            assert_eq!(rb.pop(), Some(i));
        }
        assert!(rb.pop().is_none());
    }

    #[test]
    fn overflow_drops_and_counts() {
        let mut rb = RingBuf::new(3);
        for i in 0..5 {
            rb.push(i);
        }
        assert_eq!(rb.len(), 3);
        assert_eq!(rb.stats.dropped, 2);
        assert_eq!(rb.pop(), Some(0)); // oldest survives; new arrivals dropped
    }

    #[test]
    fn drain_into_reuses_vec() {
        let mut rb = RingBuf::new(16);
        for i in 0..10 {
            rb.push(i);
        }
        let mut out = Vec::with_capacity(16);
        let n = rb.drain_into(4, &mut out);
        assert_eq!(n, 4);
        assert_eq!(out, vec![0, 1, 2, 3]);
        let n2 = rb.drain_into(100, &mut out);
        assert_eq!(n2, 6);
        assert_eq!(rb.len(), 0);
        assert_eq!(rb.stats.drained, 10);
    }

    #[test]
    fn cursor_attributes_drops_to_their_epoch() {
        let mut rb = RingBuf::new(4);
        let mut cur = RingCursor::default();
        // Epoch 1: 6 pushes into a 4-slot ring → 2 drops.
        for i in 0..6 {
            rb.push(i);
        }
        let e1 = cur.advance(&rb);
        assert_eq!(e1.pushed, 4);
        assert_eq!(e1.dropped, 2);
        assert_eq!(e1.drained, 0);
        // Consumer catches up, then epoch 2 overflows by exactly 1.
        while rb.pop().is_some() {}
        for i in 0..5 {
            rb.push(i);
        }
        let e2 = cur.advance(&rb);
        assert_eq!(e2.pushed, 4);
        assert_eq!(e2.dropped, 1);
        assert_eq!(e2.drained, 4);
        // Per-epoch drops sum to the global counter.
        assert_eq!(e1.dropped + e2.dropped, rb.stats.dropped);
        // A quiet epoch reports all-zero deltas.
        assert_eq!(cur.advance(&rb), EpochDelta::default());
    }

    #[test]
    fn fresh_cursor_starts_at_current_counters() {
        let mut rb = RingBuf::new(2);
        for i in 0..5 {
            rb.push(i);
        }
        // `cursor()` skips history; `default()` charges it to epoch 1.
        let mut at_now = rb.cursor();
        let mut from_start = RingCursor::default();
        rb.push(9);
        assert_eq!(at_now.advance(&rb).dropped, 1);
        assert_eq!(from_start.advance(&rb).dropped, 4);
    }

    #[test]
    fn peak_tracking() {
        let mut rb = RingBuf::new(100);
        for i in 0..50 {
            rb.push(i);
        }
        for _ in 0..50 {
            rb.pop();
        }
        assert_eq!(rb.stats.peak, 50);
        assert!(rb.peak_bytes() >= 50 * 4);
    }
}
