//! The circular buffer between kernel probes and the user-space probe
//! (paper Figure 2). Bounded like a perf ring buffer: when the consumer
//! falls behind, new records are *dropped* and counted, which is exactly
//! the failure mode a real deployment tunes buffer pages against.

/// Drop/throughput statistics for a ring buffer.
#[derive(Clone, Copy, Debug, Default)]
pub struct RingBufStats {
    pub pushed: u64,
    pub dropped: u64,
    pub drained: u64,
    /// High-water mark of queued records.
    pub peak: usize,
}

/// Bounded FIFO of records of type `T`.
#[derive(Debug)]
pub struct RingBuf<T> {
    buf: std::collections::VecDeque<T>,
    capacity: usize,
    pub stats: RingBufStats,
    /// Approximate bytes per record, for memory accounting.
    record_bytes: u64,
}

impl<T> RingBuf<T> {
    pub fn new(capacity: usize) -> RingBuf<T> {
        RingBuf {
            buf: std::collections::VecDeque::with_capacity(capacity.min(1 << 16)),
            capacity,
            stats: RingBufStats::default(),
            record_bytes: std::mem::size_of::<T>() as u64,
        }
    }

    /// Push a record; returns false (and counts a drop) when full.
    #[inline]
    pub fn push(&mut self, rec: T) -> bool {
        if self.buf.len() >= self.capacity {
            self.stats.dropped += 1;
            return false;
        }
        self.buf.push_back(rec);
        self.stats.pushed += 1;
        self.stats.peak = self.stats.peak.max(self.buf.len());
        true
    }

    /// Pop the oldest record.
    #[inline]
    pub fn pop(&mut self) -> Option<T> {
        let r = self.buf.pop_front();
        if r.is_some() {
            self.stats.drained += 1;
        }
        r
    }

    /// Drain up to `max` records into `out` (reuses the caller's vector —
    /// the hot path never allocates).
    pub fn drain_into(&mut self, max: usize, out: &mut Vec<T>) -> usize {
        let n = max.min(self.buf.len());
        for _ in 0..n {
            out.push(self.buf.pop_front().unwrap());
        }
        self.stats.drained += n as u64;
        n
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Peak memory footprint estimate.
    pub fn peak_bytes(&self) -> u64 {
        self.stats.peak as u64 * self.record_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut rb = RingBuf::new(8);
        for i in 0..5 {
            assert!(rb.push(i));
        }
        for i in 0..5 {
            assert_eq!(rb.pop(), Some(i));
        }
        assert!(rb.pop().is_none());
    }

    #[test]
    fn overflow_drops_and_counts() {
        let mut rb = RingBuf::new(3);
        for i in 0..5 {
            rb.push(i);
        }
        assert_eq!(rb.len(), 3);
        assert_eq!(rb.stats.dropped, 2);
        assert_eq!(rb.pop(), Some(0)); // oldest survives; new arrivals dropped
    }

    #[test]
    fn drain_into_reuses_vec() {
        let mut rb = RingBuf::new(16);
        for i in 0..10 {
            rb.push(i);
        }
        let mut out = Vec::with_capacity(16);
        let n = rb.drain_into(4, &mut out);
        assert_eq!(n, 4);
        assert_eq!(out, vec![0, 1, 2, 3]);
        let n2 = rb.drain_into(100, &mut out);
        assert_eq!(n2, 6);
        assert_eq!(rb.len(), 0);
        assert_eq!(rb.stats.drained, 10);
    }

    #[test]
    fn peak_tracking() {
        let mut rb = RingBuf::new(100);
        for i in 0..50 {
            rb.push(i);
        }
        for _ in 0..50 {
            rb.pop();
        }
        assert_eq!(rb.stats.peak, 50);
        assert!(rb.peak_bytes() >= 50 * 4);
    }
}
