//! The op-level program DSL and its interpreter.
//!
//! Synthetic application threads are small programs over ops: compute
//! bursts, pthread-style synchronization, pipeline-queue transfers, MPI
//! messages, spin loops, simulated I/O and transaction markers. The
//! interpreter implements [`TaskLogic`], translating ops into scheduler
//! actions; every op carries an instruction-pointer offset inside its
//! enclosing function so the profiler's samples and stack walks resolve
//! to plausible source lines via the app's [`SymbolTable`].
//!
//! Blocking protocols mirror the real primitives' futex behaviour:
//! mutexes hand off directly to the oldest waiter; condvars requeue onto
//! the mutex; queues and channels use wake-and-retry; InnoDB-style
//! rwlocks spin (`spin_rounds × spin_delay`) before parking — the
//! spin/park split is exactly what MySQL's `INNODB_SPIN_WAIT_DELAY`
//! experiment (§5.3) tunes.

use std::cell::RefCell;
use std::rc::Rc;

use crate::simkernel::{Pid, Step, StepCtx, TaskLogic, Time};
use crate::util::Prng;

use super::symbols::{SymId, SymbolTable, BYTES_PER_LINE};
use super::world::{ObjId, World};

/// One instruction: an op plus its IP offset within the current function.
#[derive(Clone, Debug)]
pub struct Inst {
    pub op: Op,
    pub ip_off: u64,
}

/// Program operations.
#[derive(Clone, Debug)]
pub enum Op {
    /// Enter a function (pushes a stack frame).
    Call(SymId),
    /// Leave the current function.
    Ret,
    /// Burn CPU: duration ~ Normal(mean, cv·mean), clamped ≥ 1 ns.
    Compute { mean_ns: u64, cv: f64 },
    /// Burn CPU for `base_ns + per_waiter_ns × (waiters on lock)`.
    /// Models cache-coherence degradation of a contended critical
    /// section: every waiter polling the lock word adds invalidation
    /// traffic that slows the holder (the Dedup §5.2 mechanism).
    ComputeScaled {
        base_ns: u64,
        per_waiter_ns: u64,
        lock: ObjId,
        cv: f64,
    },
    Lock(ObjId),
    Unlock(ObjId),
    /// Atomically release `mutex` and wait on `cond`; reacquires on wake.
    CondWait { cond: ObjId, mutex: ObjId },
    CondSignal(ObjId),
    CondBroadcast(ObjId),
    Barrier(ObjId),
    /// Push a token into a bounded queue (blocks while full).
    QueuePush(ObjId),
    /// Pop a token (blocks while empty).
    QueuePop(ObjId),
    /// Pop a token by *polling*: if the queue is empty, burn `poll_ns`
    /// checking (visible to the sampling profiler at this op's line),
    /// sleep `sleep_ns`, and retry. Models backoff-polling waits such as
    /// bodytrack's command wait, where the waiting function shows up in
    /// IP samples in proportion to the time spent waiting.
    QueuePollPop {
        q: ObjId,
        poll_ns: u64,
        sleep_ns: u64,
    },
    LatchSignal(ObjId),
    LatchWait(ObjId),
    /// Post an MPI-style message.
    Send(ObjId),
    /// Receive a message; `spin` busy-waits (aggressive MPI mode),
    /// otherwise the receiver blocks.
    Recv { chan: ObjId, spin: bool, poll_ns: u64 },
    /// InnoDB-style rwlock acquire: spin `spin_rounds × spin_delay_ns`
    /// then park.
    RwLock {
        lock: ObjId,
        write: bool,
        spin_rounds: u32,
        spin_delay_ns: u64,
    },
    RwUnlock { lock: ObjId, write: bool },
    /// Simulated blocking I/O or timer sleep.
    Sleep { mean_ns: u64, cv: f64 },
    SetFlag(ObjId),
    /// Busy-wait until the flag is set, polling every `poll_ns`.
    SpinUntilFlag { flag: ObjId, poll_ns: u64 },
    TxnStart,
    TxnEnd,
    /// Repeat the enclosed region `count` times.
    LoopStart { count: u64 },
    LoopEnd,
}

/// Interpreter resume state across a block/wake boundary.
#[derive(Clone, Debug, PartialEq)]
enum Resume {
    None,
    /// Re-execute the current instruction from scratch.
    Retry,
    /// Woken with the resource already owned: advance past the op.
    Advance,
    /// Condvar wake: reacquire the mutex, then advance.
    Reacquire(ObjId),
    /// Mid-spin on an rwlock: `left` spin rounds remain.
    RwSpin { left: u32 },
    /// Spin exhausted; the park overhead has been paid and the next step
    /// enqueues the task in the lock's wait array.
    RwPark,
    /// Poll burst done; sleep before re-checking the polled queue.
    PollSleep,
}

/// Cost of parking on a contended rwlock: futex syscall + reserving a
/// cell in the sync array (InnoDB's `sync_array_reserve_cell`). This is
/// what a larger `INNODB_SPIN_WAIT_DELAY` buys its way out of (§5.3).
const PARK_NS: u64 = 4_500;

/// A thread program bound to its app's shared state.
pub struct ThreadLogic {
    prog: Rc<Vec<Inst>>,
    pc: usize,
    loops: Vec<(usize, u64)>,
    world: Rc<RefCell<World>>,
    symtab: Rc<SymbolTable>,
    rng: Prng,
    resume: Resume,
    frames: Vec<SymId>,
    /// Ops executed (for debugging/telemetry).
    pub ops_executed: u64,
}

impl ThreadLogic {
    pub fn new(
        prog: Rc<Vec<Inst>>,
        world: Rc<RefCell<World>>,
        symtab: Rc<SymbolTable>,
        rng: Prng,
    ) -> Box<ThreadLogic> {
        Box::new(ThreadLogic {
            prog,
            pc: 0,
            loops: Vec::new(),
            world,
            symtab,
            rng,
            resume: Resume::None,
            frames: Vec::new(),
            ops_executed: 0,
        })
    }

    fn cur_sym(&self) -> Option<SymId> {
        self.frames.last().copied()
    }

    /// Set the task's visible IP to this instruction's location.
    fn set_ip(&self, ctx: &mut StepCtx, ip_off: u64) {
        if let Some(sym) = self.cur_sym() {
            *ctx.ip = self.symtab.ip(sym, ip_off);
        }
    }

    /// Skip from a `LoopStart` at `pc` to just past its matching `LoopEnd`.
    fn skip_loop(&self) -> usize {
        let mut depth = 0usize;
        let mut i = self.pc;
        loop {
            match &self.prog[i].op {
                Op::LoopStart { .. } => depth += 1,
                Op::LoopEnd => {
                    depth -= 1;
                    if depth == 0 {
                        return i + 1;
                    }
                }
                _ => {}
            }
            i += 1;
        }
    }
}

impl TaskLogic for ThreadLogic {
    fn step(&mut self, ctx: &mut StepCtx) -> Step {
        let pid: Pid = ctx.pid;
        let now: Time = ctx.now;
        // Handle pending resume state first.
        match std::mem::replace(&mut self.resume, Resume::None) {
            Resume::None => {}
            Resume::Retry => { /* fall through: re-execute current inst */ }
            Resume::Advance => {
                self.pc += 1;
            }
            Resume::Reacquire(m) => {
                let got = self.world.borrow_mut().mutex_lock(m, pid);
                if got {
                    self.pc += 1;
                } else {
                    // Queued on the mutex; handoff grants ownership.
                    self.resume = Resume::Advance;
                    return Step::Block;
                }
            }
            Resume::RwSpin { left } => {
                // Re-enter the RwLock op with the spin counter restored.
                self.resume = Resume::RwSpin { left };
            }
            Resume::RwPark => {
                // Re-enter the RwLock op in the parking phase.
                self.resume = Resume::RwPark;
            }
            Resume::PollSleep => {
                self.resume = Resume::PollSleep;
            }
        }

        let mut guard = 0u32;
        loop {
            guard += 1;
            if guard > 100_000 {
                panic!("thread {pid} stuck in zero-time op loop at pc={}", self.pc);
            }
            if self.pc >= self.prog.len() {
                return Step::Exit;
            }
            let inst = self.prog[self.pc].clone();
            self.ops_executed += 1;
            self.set_ip(ctx, inst.ip_off);
            match inst.op {
                Op::Call(sym) => {
                    self.frames.push(sym);
                    ctx.stack.push(self.symtab.addr_of(sym));
                    *ctx.ip = self.symtab.addr_of(sym);
                    self.pc += 1;
                }
                Op::Ret => {
                    self.frames.pop();
                    ctx.stack.pop();
                    self.pc += 1;
                }
                Op::Compute { mean_ns, cv } => {
                    self.pc += 1;
                    let ns = if cv == 0.0 {
                        mean_ns.max(1)
                    } else {
                        self.rng.dur(mean_ns, cv)
                    };
                    return Step::Compute { ns };
                }
                Op::ComputeScaled {
                    base_ns,
                    per_waiter_ns,
                    lock,
                    cv,
                } => {
                    self.pc += 1;
                    let waiters =
                        self.world.borrow().mutexes[lock].waiters.len() as u64;
                    let mean = base_ns + per_waiter_ns * waiters;
                    let ns = if cv == 0.0 {
                        mean.max(1)
                    } else {
                        self.rng.dur(mean, cv)
                    };
                    return Step::Compute { ns };
                }
                Op::Lock(m) => {
                    let got = self.world.borrow_mut().mutex_lock(m, pid);
                    if got {
                        self.pc += 1;
                    } else {
                        self.resume = Resume::Advance; // handoff grants lock
                        *ctx.wait_kind = crate::simkernel::WaitKind::Futex;
                        return Step::Block;
                    }
                }
                Op::Unlock(m) => {
                    if let Some(next) = self.world.borrow_mut().mutex_unlock(m, pid) {
                        ctx.wake(next);
                    }
                    self.pc += 1;
                }
                Op::CondWait { cond, mutex } => {
                    let mut w = self.world.borrow_mut();
                    w.cond_enqueue(cond, pid);
                    if let Some(next) = w.mutex_unlock(mutex, pid) {
                        ctx.wake(next);
                    }
                    drop(w);
                    self.resume = Resume::Reacquire(mutex);
                    *ctx.wait_kind = crate::simkernel::WaitKind::Futex;
                    return Step::Block;
                }
                Op::CondSignal(c) => {
                    if let Some(p) = self.world.borrow_mut().cond_signal(c) {
                        ctx.wake(p);
                    }
                    self.pc += 1;
                }
                Op::CondBroadcast(c) => {
                    for p in self.world.borrow_mut().cond_broadcast(c) {
                        ctx.wake(p);
                    }
                    self.pc += 1;
                }
                Op::Barrier(b) => {
                    match self.world.borrow_mut().barrier_arrive(b, pid) {
                        Some(waiters) => {
                            for p in waiters {
                                ctx.wake(p);
                            }
                            self.pc += 1;
                        }
                        None => {
                            self.resume = Resume::Advance;
                            *ctx.wait_kind = crate::simkernel::WaitKind::Barrier;
                            return Step::Block;
                        }
                    }
                }
                Op::QueuePush(q) => {
                    match self.world.borrow_mut().queue_try_push(q, pid) {
                        Ok(woken) => {
                            if let Some(p) = woken {
                                ctx.wake(p);
                            }
                            self.pc += 1;
                        }
                        Err(()) => {
                            self.resume = Resume::Retry;
                            *ctx.wait_kind = crate::simkernel::WaitKind::Queue;
                            return Step::Block;
                        }
                    }
                }
                Op::QueuePollPop { q, poll_ns, sleep_ns } => {
                    if matches!(self.resume, Resume::PollSleep) {
                        // Burst finished: sleep, then retry the pop. The
                        // ±25% jitter mirrors real timer slack and keeps
                        // co-released pollers from phase-locking.
                        self.resume = Resume::None;
                        return Step::Sleep {
                            ns: self.rng.dur(sleep_ns.max(1), 0.25),
                        };
                    }
                    let got = {
                        let mut w = self.world.borrow_mut();
                        match w.queue_try_pop(q, pid) {
                            Ok(woken) => {
                                if let Some(p) = woken {
                                    ctx.wake(p);
                                }
                                true
                            }
                            Err(()) => {
                                // queue_try_pop queued us, but polling
                                // waits are not woken by pushers —
                                // remove the registration again.
                                if let Some(pos) = w.queues[q]
                                    .pop_waiters
                                    .iter()
                                    .position(|p| *p == pid)
                                {
                                    w.queues[q].pop_waiters.remove(pos);
                                }
                                false
                            }
                        }
                    };
                    if got {
                        self.pc += 1;
                    } else {
                        self.resume = Resume::PollSleep;
                        return Step::Compute { ns: poll_ns.max(1) };
                    }
                }
                Op::QueuePop(q) => {
                    match self.world.borrow_mut().queue_try_pop(q, pid) {
                        Ok(woken) => {
                            if let Some(p) = woken {
                                ctx.wake(p);
                            }
                            self.pc += 1;
                        }
                        Err(()) => {
                            self.resume = Resume::Retry;
                            *ctx.wait_kind = crate::simkernel::WaitKind::Queue;
                            return Step::Block;
                        }
                    }
                }
                Op::LatchSignal(l) => {
                    for p in self.world.borrow_mut().latch_signal(l) {
                        ctx.wake(p);
                    }
                    self.pc += 1;
                }
                Op::LatchWait(l) => {
                    let open = self.world.borrow_mut().latch_wait(l, pid);
                    if open {
                        self.pc += 1;
                    } else {
                        self.resume = Resume::Advance;
                        *ctx.wait_kind = crate::simkernel::WaitKind::Barrier;
                        return Step::Block;
                    }
                }
                Op::Send(ch) => {
                    if let Some(p) = self.world.borrow_mut().chan_send(ch) {
                        ctx.wake(p);
                    }
                    self.pc += 1;
                }
                Op::Recv { chan, spin, poll_ns } => {
                    let got = self
                        .world
                        .borrow_mut()
                        .chan_try_recv(chan, pid, !spin);
                    if got {
                        self.pc += 1;
                    } else if spin {
                        // Busy-wait: stay on this op, consume CPU polling.
                        return Step::Compute { ns: poll_ns.max(1) };
                    } else {
                        self.resume = Resume::Retry;
                        *ctx.wait_kind = crate::simkernel::WaitKind::Channel;
                        return Step::Block;
                    }
                }
                Op::RwLock {
                    lock,
                    write,
                    spin_rounds,
                    spin_delay_ns,
                } => {
                    let state = std::mem::replace(&mut self.resume, Resume::None);
                    let got = self.world.borrow_mut().rw_try(lock, pid, write);
                    if got {
                        self.pc += 1;
                        continue;
                    }
                    if matches!(state, Resume::RwPark) {
                        // Park overhead already paid: join the wait array.
                        self.world.borrow_mut().rw_enqueue(lock, pid, write);
                        self.resume = Resume::Retry;
                        *ctx.wait_kind = crate::simkernel::WaitKind::Futex;
                        return Step::Block;
                    }
                    // Spin phase, then pay the park overhead.
                    let left = match state {
                        Resume::RwSpin { left } => left,
                        _ => spin_rounds,
                    };
                    if left > 0 {
                        self.resume = Resume::RwSpin { left: left - 1 };
                        return Step::Compute {
                            ns: spin_delay_ns.max(1),
                        };
                    }
                    self.resume = Resume::RwPark;
                    return Step::Compute { ns: PARK_NS };
                }
                Op::RwUnlock { lock, write } => {
                    for p in self.world.borrow_mut().rw_unlock(lock, pid, write) {
                        ctx.wake(p);
                    }
                    self.pc += 1;
                }
                Op::Sleep { mean_ns, cv } => {
                    self.pc += 1;
                    let ns = if cv == 0.0 {
                        mean_ns.max(1)
                    } else {
                        self.rng.dur(mean_ns, cv)
                    };
                    return Step::Sleep { ns };
                }
                Op::SetFlag(f) => {
                    self.world.borrow_mut().set_flag(f);
                    self.pc += 1;
                }
                Op::SpinUntilFlag { flag, poll_ns } => {
                    if self.world.borrow().flag(flag) {
                        self.pc += 1;
                    } else {
                        return Step::Compute { ns: poll_ns.max(1) };
                    }
                }
                Op::TxnStart => {
                    self.world.borrow_mut().txn_start(pid, now);
                    self.pc += 1;
                }
                Op::TxnEnd => {
                    self.world.borrow_mut().txn_end(pid, now);
                    self.pc += 1;
                }
                Op::LoopStart { count } => {
                    if count == 0 {
                        self.pc = self.skip_loop();
                    } else {
                        self.loops.push((self.pc, count));
                        self.pc += 1;
                    }
                }
                Op::LoopEnd => {
                    let (start, left) = self.loops.pop().expect("LoopEnd without LoopStart");
                    if left > 1 {
                        self.loops.push((start, left - 1));
                        self.pc = start + 1;
                    } else {
                        self.pc += 1;
                    }
                }
            }
        }
    }
}

/// Builder for thread programs: assigns IP offsets sequentially within
/// the current function so every op lands on its own source line.
pub struct ProgramBuilder<'a> {
    symtab: &'a mut SymbolTable,
    insts: Vec<Inst>,
    /// (sym, next line-slot) per open frame.
    frames: Vec<(SymId, u64)>,
}

impl<'a> ProgramBuilder<'a> {
    pub fn new(symtab: &'a mut SymbolTable) -> ProgramBuilder<'a> {
        ProgramBuilder {
            symtab,
            insts: Vec::new(),
            frames: Vec::new(),
        }
    }

    fn next_off(&mut self) -> u64 {
        match self.frames.last_mut() {
            Some((_, slot)) => {
                let off = *slot * BYTES_PER_LINE;
                *slot += 1;
                off
            }
            None => 0,
        }
    }

    fn push(&mut self, op: Op) -> &mut Self {
        let ip_off = self.next_off();
        self.insts.push(Inst { op, ip_off });
        self
    }

    /// Enter a function, registering the symbol on first use.
    pub fn call(&mut self, name: &str, file: &str, line: u32) -> &mut Self {
        // Reuse an existing symbol with this name if present (functions
        // are shared across threads).
        let sym = (0..self.symtab.len())
            .find(|i| self.symtab.func(*i).name == name)
            .unwrap_or_else(|| self.symtab.add(name, file, line));
        self.insts.push(Inst {
            op: Op::Call(sym),
            ip_off: 0,
        });
        self.frames.push((sym, 1));
        self
    }

    pub fn ret(&mut self) -> &mut Self {
        self.insts.push(Inst { op: Op::Ret, ip_off: 0 });
        self.frames.pop();
        self
    }

    pub fn compute(&mut self, mean_ns: u64, cv: f64) -> &mut Self {
        self.push(Op::Compute { mean_ns, cv })
    }

    /// Compute whose duration grows with the number of waiters on `lock`
    /// (see [`Op::ComputeScaled`]).
    pub fn compute_scaled(
        &mut self,
        base_ns: u64,
        per_waiter_ns: u64,
        lock: ObjId,
        cv: f64,
    ) -> &mut Self {
        self.push(Op::ComputeScaled {
            base_ns,
            per_waiter_ns,
            lock,
            cv,
        })
    }

    pub fn lock(&mut self, m: ObjId) -> &mut Self {
        self.push(Op::Lock(m))
    }

    pub fn unlock(&mut self, m: ObjId) -> &mut Self {
        self.push(Op::Unlock(m))
    }

    pub fn cond_wait(&mut self, cond: ObjId, mutex: ObjId) -> &mut Self {
        self.push(Op::CondWait { cond, mutex })
    }

    pub fn cond_signal(&mut self, c: ObjId) -> &mut Self {
        self.push(Op::CondSignal(c))
    }

    pub fn cond_broadcast(&mut self, c: ObjId) -> &mut Self {
        self.push(Op::CondBroadcast(c))
    }

    pub fn barrier(&mut self, b: ObjId) -> &mut Self {
        self.push(Op::Barrier(b))
    }

    pub fn queue_push(&mut self, q: ObjId) -> &mut Self {
        self.push(Op::QueuePush(q))
    }

    pub fn queue_pop(&mut self, q: ObjId) -> &mut Self {
        self.push(Op::QueuePop(q))
    }

    pub fn queue_poll_pop(&mut self, q: ObjId, poll_ns: u64, sleep_ns: u64) -> &mut Self {
        self.push(Op::QueuePollPop { q, poll_ns, sleep_ns })
    }

    pub fn latch_signal(&mut self, l: ObjId) -> &mut Self {
        self.push(Op::LatchSignal(l))
    }

    pub fn latch_wait(&mut self, l: ObjId) -> &mut Self {
        self.push(Op::LatchWait(l))
    }

    pub fn send(&mut self, ch: ObjId) -> &mut Self {
        self.push(Op::Send(ch))
    }

    pub fn recv(&mut self, chan: ObjId, spin: bool, poll_ns: u64) -> &mut Self {
        self.push(Op::Recv { chan, spin, poll_ns })
    }

    pub fn rw_lock(
        &mut self,
        lock: ObjId,
        write: bool,
        spin_rounds: u32,
        spin_delay_ns: u64,
    ) -> &mut Self {
        self.push(Op::RwLock {
            lock,
            write,
            spin_rounds,
            spin_delay_ns,
        })
    }

    pub fn rw_unlock(&mut self, lock: ObjId, write: bool) -> &mut Self {
        self.push(Op::RwUnlock { lock, write })
    }

    pub fn sleep(&mut self, mean_ns: u64, cv: f64) -> &mut Self {
        self.push(Op::Sleep { mean_ns, cv })
    }

    pub fn set_flag(&mut self, f: ObjId) -> &mut Self {
        self.push(Op::SetFlag(f))
    }

    pub fn spin_until(&mut self, flag: ObjId, poll_ns: u64) -> &mut Self {
        self.push(Op::SpinUntilFlag { flag, poll_ns })
    }

    pub fn txn_start(&mut self) -> &mut Self {
        self.push(Op::TxnStart)
    }

    pub fn txn_end(&mut self) -> &mut Self {
        self.push(Op::TxnEnd)
    }

    pub fn loop_start(&mut self, count: u64) -> &mut Self {
        self.push(Op::LoopStart { count })
    }

    pub fn loop_end(&mut self) -> &mut Self {
        self.push(Op::LoopEnd)
    }

    pub fn build(&mut self) -> Rc<Vec<Inst>> {
        Rc::new(std::mem::take(&mut self.insts))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkernel::{Kernel, KernelConfig};

    fn harness(
        cpus: usize,
        build: impl FnOnce(&mut SymbolTable, &mut World) -> Vec<(String, Rc<Vec<Inst>>)>,
    ) -> (Kernel, Rc<RefCell<World>>, u64) {
        let mut st = SymbolTable::new();
        let mut w = World::new();
        let progs = build(&mut st, &mut w);
        let symtab = Rc::new(st);
        let world = Rc::new(RefCell::new(w));
        let mut k = Kernel::new(KernelConfig {
            cpus,
            switch_cost_ns: 0,
            ..Default::default()
        });
        let mut rng = Prng::new(1);
        for (comm, prog) in progs {
            let logic = ThreadLogic::new(
                prog,
                world.clone(),
                symtab.clone(),
                rng.fork(comm.len() as u64),
            );
            let pid = k.spawn(&comm, logic);
            k.track(pid);
        }
        let end = k.run().unwrap();
        (k, world, end)
    }

    #[test]
    fn compute_loop_runs_to_completion() {
        let (_, _, end) = harness(1, |st, _| {
            let mut b = ProgramBuilder::new(st);
            b.call("main", "t.c", 1)
                .loop_start(10)
                .compute(1_000, 0.0)
                .loop_end()
                .ret();
            vec![("t".to_string(), b.build())]
        });
        assert_eq!(end, 10_000);
    }

    #[test]
    fn mutex_serializes_critical_sections() {
        let (_, world, end) = harness(4, |st, w| {
            let m = w.new_mutex();
            let mut progs = Vec::new();
            for i in 0..4 {
                let mut b = ProgramBuilder::new(st);
                b.call("worker", "t.c", 1)
                    .loop_start(5)
                    .lock(m)
                    .compute(10_000, 0.0)
                    .unlock(m)
                    .loop_end()
                    .ret();
                progs.push((format!("w{i}"), b.build()));
            }
            progs
        });
        // 4 threads × 5 critical sections × 10 µs, fully serialized.
        assert!(end >= 200_000, "end={end}");
        let w = world.borrow();
        assert_eq!(w.mutexes[0].acquisitions, 20);
        assert!(w.mutexes[0].contended > 0);
    }

    #[test]
    fn condvar_producer_consumer() {
        let (_, _, end) = harness(2, |st, w| {
            let m = w.new_mutex();
            let c = w.new_cond();
            let f = w.new_flag();
            let mut prod = ProgramBuilder::new(st);
            prod.call("producer", "t.c", 1)
                .compute(50_000, 0.0)
                .lock(m)
                .set_flag(f)
                .cond_signal(c)
                .unlock(m)
                .ret();
            let prod_prog = prod.build();
            let mut cons = ProgramBuilder::new(st);
            cons.call("consumer", "t.c", 20)
                .lock(m)
                .cond_wait(c, m) // flag is never set before the wait here
                .unlock(m)
                .compute(10_000, 0.0)
                .ret();
            vec![
                ("cons".to_string(), cons.build()),
                ("prod".to_string(), prod_prog),
            ]
        });
        // Consumer waits ~50 µs for the producer, then 10 µs of work.
        assert!(end >= 60_000, "end={end}");
    }

    #[test]
    fn barrier_synchronizes_phases() {
        let (k, _, end) = harness(4, |st, w| {
            let b = w.new_barrier(4);
            let mut progs = Vec::new();
            for i in 0..4u64 {
                let mut pb = ProgramBuilder::new(st);
                pb.call("phase_worker", "t.c", 1)
                    .compute(10_000 * (i + 1), 0.0) // imbalanced
                    .barrier(b)
                    .compute(5_000, 0.0)
                    .ret();
                progs.push((format!("w{i}"), pb.build()));
            }
            progs
        });
        // All wait for the slowest (40 µs), then 5 µs more.
        assert!(end >= 45_000, "end={end}");
        assert!(end < 60_000, "end={end}");
        assert!(k.stats.wakeups >= 3);
    }

    #[test]
    fn queue_pipeline_transfers_all_items() {
        let (_, world, _) = harness(2, |st, w| {
            let q = w.new_queue(4);
            let mut prod = ProgramBuilder::new(st);
            prod.call("producer", "t.c", 1)
                .loop_start(20)
                .compute(1_000, 0.0)
                .queue_push(q)
                .loop_end()
                .ret();
            let prod_prog = prod.build();
            let mut cons = ProgramBuilder::new(st);
            cons.call("consumer", "t.c", 10)
                .loop_start(20)
                .queue_pop(q)
                .compute(2_000, 0.0)
                .loop_end()
                .ret();
            vec![
                ("prod".to_string(), prod_prog),
                ("cons".to_string(), cons.build()),
            ]
        });
        assert_eq!(world.borrow().queues[0].total_pushed, 20);
        assert_eq!(world.borrow().queues[0].tokens, 0);
    }

    #[test]
    fn spin_wait_consumes_cpu_while_waiting() {
        let (k, _, _) = harness(2, |st, w| {
            let f = w.new_flag();
            let mut setter = ProgramBuilder::new(st);
            setter
                .call("setter", "t.c", 1)
                .compute(100_000, 0.0)
                .set_flag(f)
                .ret();
            let setter_prog = setter.build();
            let mut spinner = ProgramBuilder::new(st);
            spinner
                .call("spinner", "t.c", 10)
                .spin_until(f, 1_000)
                .ret();
            vec![
                ("set".to_string(), setter_prog),
                ("spin".to_string(), spinner.build()),
            ]
        });
        // The spinner burned ~100 µs of CPU while "waiting".
        let spinner = k.all_tasks().find(|t| t.comm == "spin").unwrap();
        assert!(spinner.cpu_time >= 90_000, "cpu={}", spinner.cpu_time);
    }

    #[test]
    fn rwlock_spin_then_block() {
        let (_, world, _) = harness(2, |st, w| {
            let rw = w.new_rwlock();
            let mut writer = ProgramBuilder::new(st);
            writer
                .call("writer", "t.c", 1)
                .rw_lock(rw, true, 0, 0)
                .compute(200_000, 0.0)
                .rw_unlock(rw, true)
                .ret();
            let writer_prog = writer.build();
            let mut reader = ProgramBuilder::new(st);
            reader
                .call("reader", "t.c", 10)
                .compute(1_000, 0.0) // let the writer go first
                .rw_lock(rw, false, 6, 2_000) // spins 6×2 µs, then parks
                .compute(1_000, 0.0)
                .rw_unlock(rw, false)
                .ret();
            vec![
                ("wr".to_string(), writer_prog),
                ("rd".to_string(), reader.build()),
            ]
        });
        assert!(world.borrow().rwlocks[0].contended > 0);
        assert!(world.borrow().rwlocks[0].writer.is_none());
        assert_eq!(world.borrow().rwlocks[0].readers, 0);
    }

    #[test]
    fn mpi_blocking_recv() {
        let (_, _, end) = harness(2, |st, w| {
            let ch = w.new_channel();
            let mut sender = ProgramBuilder::new(st);
            sender
                .call("rank0", "mpi.c", 1)
                .compute(30_000, 0.0)
                .send(ch)
                .ret();
            let sender_prog = sender.build();
            let mut recver = ProgramBuilder::new(st);
            recver
                .call("rank1", "mpi.c", 10)
                .recv(ch, false, 0)
                .compute(5_000, 0.0)
                .ret();
            vec![
                ("r0".to_string(), sender_prog),
                ("r1".to_string(), recver.build()),
            ]
        });
        assert!(end >= 35_000, "end={end}");
    }

    #[test]
    fn mpi_spinning_recv_is_active() {
        let (k, _, _) = harness(2, |st, w| {
            let ch = w.new_channel();
            let mut sender = ProgramBuilder::new(st);
            sender
                .call("rank0", "mpi.c", 1)
                .compute(50_000, 0.0)
                .send(ch)
                .ret();
            let sender_prog = sender.build();
            let mut recver = ProgramBuilder::new(st);
            recver
                .call("rank1", "mpi.c", 10)
                .recv(ch, true, 500)
                .ret();
            vec![
                ("r0".to_string(), sender_prog),
                ("r1".to_string(), recver.build()),
            ]
        });
        let spinner = k.all_tasks().find(|t| t.comm == "r1").unwrap();
        // Aggressive mode: receiver consumed CPU the entire wait.
        assert!(spinner.cpu_time >= 45_000, "cpu={}", spinner.cpu_time);
    }

    #[test]
    fn latch_join_semantics() {
        let (_, _, end) = harness(4, |st, w| {
            let l = w.new_latch(3);
            let mut progs = Vec::new();
            for i in 0..3u64 {
                let mut b = ProgramBuilder::new(st);
                b.call("worker", "t.c", 1)
                    .compute(10_000 + i * 5_000, 0.0)
                    .latch_signal(l)
                    .ret();
                progs.push((format!("w{i}"), b.build()));
            }
            let mut main = ProgramBuilder::new(st);
            main.call("main", "t.c", 50)
                .latch_wait(l)
                .compute(1_000, 0.0)
                .ret();
            progs.push(("main".to_string(), main.build()));
            progs
        });
        // Main waits for the slowest worker (20 µs) then runs 1 µs.
        assert!(end >= 21_000, "end={end}");
    }

    #[test]
    fn txn_latencies_collected() {
        let (_, world, _) = harness(1, |st, w| {
            let _ = w;
            let mut b = ProgramBuilder::new(st);
            b.call("client", "t.c", 1)
                .loop_start(5)
                .txn_start()
                .compute(10_000, 0.0)
                .txn_end()
                .loop_end()
                .ret();
            vec![("c".to_string(), b.build())]
        });
        let lat = world.borrow().latencies.clone();
        assert_eq!(lat.len(), 5);
        assert!(lat.iter().all(|l| *l >= 10_000));
    }

    #[test]
    fn nested_loops_and_zero_loops() {
        let (_, _, end) = harness(1, |st, _| {
            let mut b = ProgramBuilder::new(st);
            b.call("main", "t.c", 1)
                .loop_start(3)
                .loop_start(2)
                .compute(1_000, 0.0)
                .loop_end()
                .loop_end()
                .loop_start(0) // skipped entirely
                .compute(1_000_000, 0.0)
                .loop_end()
                .ret();
            vec![("t".to_string(), b.build())]
        });
        assert_eq!(end, 6_000);
    }

    #[test]
    fn ip_and_stack_tracked() {
        let (k, _, _) = harness(1, |st, _| {
            let mut b = ProgramBuilder::new(st);
            b.call("main", "t.c", 1)
                .call("inner", "t.c", 100)
                .compute(1_000, 0.0)
                .ret()
                .ret();
            vec![("t".to_string(), b.build())]
        });
        // After exit the stack is empty, but the task ran: ip was set.
        let t = k.all_tasks().next().unwrap();
        assert!(t.ip != 0);
        assert!(t.stack.is_empty());
    }
}
