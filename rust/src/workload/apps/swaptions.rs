//! Swaptions: Monte-Carlo swaption pricing with *block* partitioning.
//!
//! 96 swaptions over N threads: the block split gives some threads two
//! swaptions and some one — a 2× load imbalance that leaves the heavy
//! half executing `HJM_SimPath_Forward_Blocking` (Table-2 critical
//! function) while the light half has exited. CR is tiny (paper: 0.07%)
//! because the imbalance tail is short relative to the run.

use crate::workload::{App, AppBuilder, ProgramBuilder};

pub const NUM_SWAPTIONS: usize = 96;

pub fn swaptions(threads: usize, seed: u64) -> App {
    let mut ab = AppBuilder::new("swaptions", seed);
    let done = ab.world.new_latch(threads as u64);

    // Block partition, exactly like the Parsec kernel: thread i gets
    // ceil/floor share of contiguous swaptions.
    let base = NUM_SWAPTIONS / threads;
    let extra = NUM_SWAPTIONS % threads;
    for i in 0..threads {
        let mine = base + usize::from(i < extra);
        let mut b = ProgramBuilder::new(&mut ab.symtab);
        b.call("worker", "HJM_Securities.cpp", 90)
            .loop_start(mine as u64);
        b.call("HJM_Swaption_Blocking", "HJM_Swaption_Blocking.cpp", 56)
            .call("HJM_SimPath_Forward_Blocking", "HJM_SimPath_Forward_Blocking.cpp", 45)
            .compute(2_600_000, 0.04)
            .ret()
            .compute(300_000, 0.04)
            .ret();
        b.loop_end().latch_signal(done).ret();
        let prog_ = b.build();
        ab.thread(&format!("swapt-{i}"), prog_);
    }

    let mut m = ProgramBuilder::new(&mut ab.symtab);
    m.call("main", "HJM_Securities.cpp", 300)
        .compute(300_000, 0.02)
        .latch_wait(done)
        .compute(120_000, 0.02)
        .ret();
    let prog_ = m.build();
        ab.thread("swaptions", prog_);

    ab.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkernel::{Kernel, KernelConfig};

    #[test]
    fn block_partition_imbalance_shows_in_runtime() {
        // 64 threads, 96 swaptions: 32 threads get 2, 32 get 1.
        let app = swaptions(64, 5);
        let mut k = Kernel::new(KernelConfig::default());
        app.spawn_into(&mut k);
        let end = k.run().unwrap();
        // Runtime tracks the 2-swaption threads: ≥ 2 × ~2.9 ms.
        assert!(end >= 5_000_000, "end={end}");
        assert!(end <= 9_000_000, "end={end}");
    }
}
