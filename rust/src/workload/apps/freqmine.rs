//! Freqmine: FP-growth frequent-itemset mining (the only OpenMP app in
//! the suite).
//!
//! OpenMP `parallel for` regions with *static* chunking over items whose
//! cost is heavy-tailed: `FPArray_scan2_DB` (Table-2 critical function)
//! takes much longer for dense transaction groups, so some chunks run
//! far past the implicit region barrier where every other thread waits.
//! CR ≈ 13% in the paper — much higher than the other data-parallel
//! apps, because the tail is long.

use crate::util::Prng;
use crate::workload::{App, AppBuilder, ProgramBuilder};

pub fn freqmine(threads: usize, seed: u64) -> App {
    let mut ab = AppBuilder::new("freqmine", seed);
    let region_barrier = ab.world.new_barrier(threads);
    let mut rng = Prng::new(seed ^ 0xF4E9);

    // 6 parallel regions (database scan passes); in each, thread i's
    // static chunk has a heavy-tailed cost: ~15% of chunks are 3-6× the
    // base cost.
    let regions = 6;
    let costs: Vec<Vec<u64>> = (0..regions)
        .map(|_| {
            (0..threads)
                .map(|_| {
                    let base = 800_000.0;
                    let mult = if rng.chance(0.15) {
                        3.0 + 3.0 * rng.f64()
                    } else {
                        0.8 + 0.4 * rng.f64()
                    };
                    (base * mult) as u64
                })
                .collect()
        })
        .collect();

    for i in 0..threads {
        let mut b = ProgramBuilder::new(&mut ab.symtab);
        b.call("FP_growth", "fp_tree.cpp", 1900);
        for r in 0..regions {
            b.call("FPArray_scan2_DB", "fp_tree.cpp", 810)
                .compute(costs[r][i], 0.06)
                .ret();
            // OpenMP implicit barrier at region end.
            b.call("__kmp_join_barrier", "kmp_barrier.cpp", 1400)
                .barrier(region_barrier)
                .ret();
        }
        // Serial tree-build section executed by thread 0 only while the
        // team waits in the next region's fork barrier.
        if i == 0 {
            b.call("FPTree_insert", "fp_tree.cpp", 500)
                .compute(2_500_000, 0.05)
                .ret();
        }
        b.ret();
        let prog_ = b.build();
        ab.thread(&format!("freqmine-{i}"), prog_);
    }

    ab.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkernel::{Kernel, KernelConfig};

    #[test]
    fn heavy_tail_dominates_regions() {
        let app = freqmine(16, 11);
        let mut k = Kernel::new(KernelConfig::default());
        app.spawn_into(&mut k);
        let end = k.run().unwrap();
        // Every region is at least base cost; tails push well past it.
        assert!(end >= 6 * 800_000, "end={end}");
        assert_eq!(app.world.borrow().barriers[0].generation, 6);
    }
}
