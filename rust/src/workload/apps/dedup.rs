//! Dedup: 5-stage deduplication/compression pipeline — the paper's
//! thread-allocation case study.
//!
//! Stages: Fragment (1) → FragmentRefine (n) → Deduplicate (n) →
//! Compress (n) → Reorder (1). `deflate_slow` in Compress (Table-2
//! critical function) contains an allocator critical section whose
//! effective cost *grows with the number of waiters* (cache-line
//! bouncing of the lock word — see `Op::ComputeScaled`), which is why
//! the paper found:
//!
//! * 1-16-16-28-1 (more Compress threads) — *slower* than the default,
//! * 1-20-20-15-1 (fewer Compress threads) — ~14% *faster*.
//!
//! Reorder's `write_file` is the known serial bottleneck [12] and shows
//! up as the second critical path.

use crate::workload::{App, AppBuilder, ProgramBuilder};

/// Thread allocation across the three parallel stages.
#[derive(Clone, Copy, Debug)]
pub struct DedupConfig {
    pub refine: usize,
    pub dedup: usize,
    pub compress: usize,
    pub chunks: u64,
}

impl Default for DedupConfig {
    fn default() -> Self {
        // The paper's initial allocation: 1-20-20-20-1.
        DedupConfig {
            refine: 20,
            dedup: 20,
            compress: 20,
            chunks: 400,
        }
    }
}

impl DedupConfig {
    pub fn with_alloc(refine: usize, dedup: usize, compress: usize) -> Self {
        DedupConfig {
            refine,
            dedup,
            compress,
            ..Default::default()
        }
    }
}

fn split(total: u64, parts: usize) -> Vec<u64> {
    let base = total / parts as u64;
    let extra = (total % parts as u64) as usize;
    (0..parts).map(|i| base + u64::from(i < extra)).collect()
}

pub fn dedup(seed: u64, cfg: DedupConfig) -> App {
    let mut ab = AppBuilder::new("dedup", seed);
    let q1 = ab.world.new_queue(32); // Fragment -> Refine
    let q2 = ab.world.new_queue(32); // Refine -> Dedup
    let q3 = ab.world.new_queue(32); // Dedup -> Compress
    let q4 = ab.world.new_queue(32); // Compress -> Reorder
    let hash_lock = ab.world.new_mutex(); // dedup hash-table lock
    let alloc_lock = ab.world.new_mutex(); // allocator lock in compress
    let n = cfg.chunks;

    // Fragment: single thread, cheap chunking.
    let mut frag = ProgramBuilder::new(&mut ab.symtab);
    frag.call("Fragment", "dedup.c", 210)
        .loop_start(n)
        .compute(25_000, 0.05)
        .queue_push(q1)
        .loop_end()
        .ret();
    let prog_ = frag.build();
        ab.thread("dedup-frag", prog_);

    // FragmentRefine: rolling-hash sub-chunking.
    for (i, mine) in split(n, cfg.refine).iter().enumerate() {
        let mut b = ProgramBuilder::new(&mut ab.symtab);
        b.call("FragmentRefine", "dedup.c", 260)
            .loop_start(*mine)
            .queue_pop(q1)
            .compute(150_000, 0.10)
            .queue_push(q2)
            .loop_end()
            .ret();
        let prog_ = b.build();
        ab.thread(&format!("dedup-refine{i}"), prog_);
    }

    // Deduplicate: hash lookup under a short lock.
    for (i, mine) in split(n, cfg.dedup).iter().enumerate() {
        let mut b = ProgramBuilder::new(&mut ab.symtab);
        b.call("Deduplicate", "dedup.c", 310)
            .loop_start(*mine)
            .queue_pop(q2)
            .compute(110_000, 0.10)
            .lock(hash_lock)
            .compute(6_000, 0.10)
            .unlock(hash_lock)
            .queue_push(q3)
            .loop_end()
            .ret();
        let prog_ = b.build();
        ab.thread(&format!("dedup-dedup{i}"), prog_);
    }

    // Compress: deflate_slow with the contention-scaled allocator
    // critical section.
    for (i, mine) in split(n, cfg.compress).iter().enumerate() {
        let mut b = ProgramBuilder::new(&mut ab.symtab);
        b.call("Compress", "dedup.c", 360).loop_start(*mine);
        b.queue_pop(q3);
        b.call("deflate_slow", "deflate.c", 1045)
            .compute(360_000, 0.08)
            .lock(alloc_lock)
            .compute_scaled(22_000, 1_800, alloc_lock, 0.05)
            .unlock(alloc_lock)
            .ret();
        b.queue_push(q4);
        b.loop_end().ret();
        let prog_ = b.build();
        ab.thread(&format!("dedup-comp{i}"), prog_);
    }

    // Reorder: single thread, writes compressed chunks to disk.
    let mut reorder = ProgramBuilder::new(&mut ab.symtab);
    reorder
        .call("Reorder", "dedup.c", 410)
        .loop_start(n)
        .queue_pop(q4)
        .call("write_file", "dedup.c", 150)
        .compute(18_000, 0.08)
        .sleep(12_000, 0.2) // async write completion
        .ret()
        .loop_end()
        .ret();
    let prog_ = reorder.build();
        ab.thread("dedup-reorder", prog_);

    ab.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkernel::{Kernel, KernelConfig};

    fn run(cfg: DedupConfig) -> u64 {
        let app = dedup(17, cfg);
        let mut k = Kernel::new(KernelConfig::default());
        app.spawn_into(&mut k);
        k.run().unwrap()
    }

    #[test]
    fn fewer_compress_threads_run_faster() {
        let base = run(DedupConfig::default()); // 20-20-20
        let fewer = run(DedupConfig::with_alloc(20, 20, 15)); // paper's fix
        let gain = (base as f64 - fewer as f64) / base as f64;
        // Paper: 14% improvement. Shape: 5%..30%.
        assert!(
            (0.05..0.30).contains(&gain),
            "base={base} fewer={fewer} gain={gain:.3}"
        );
    }

    #[test]
    fn more_compress_threads_run_slower() {
        let base = run(DedupConfig::default());
        let more = run(DedupConfig::with_alloc(16, 16, 28)); // paper's misstep
        assert!(more > base, "more={more} base={base}");
    }

    #[test]
    fn pipeline_conserves_chunks() {
        let cfg = DedupConfig {
            chunks: 80,
            ..DedupConfig::with_alloc(4, 4, 4)
        };
        let app = dedup(3, cfg);
        let mut k = Kernel::new(KernelConfig::default());
        app.spawn_into(&mut k);
        k.run().unwrap();
        let w = app.world.borrow();
        for q in 0..4 {
            assert_eq!(w.queues[q].total_pushed, 80, "queue {q}");
            assert_eq!(w.queues[q].tokens, 0, "queue {q}");
        }
    }
}
