//! Ferret: 6-stage content-based similarity-search pipeline — the
//! paper's Figure-4 case study.
//!
//! Stages: load (serial) → segment → extract → index → rank → output
//! (serial), connected by bounded queues. The rank stage's
//! `emd()`/`dist_L2_float()` (Table-2 critical functions) is ~20× the
//! cost of segmentation, so the default 15-15-15-15 allocation leaves
//! rank starved of threads and everyone else blocked on full/empty
//! queues. The paper rebalances to 2-1-18-39 for a ~50% runtime cut
//! (and compares against [10]'s suggested 20-1-22-21).

use std::rc::Rc;

use crate::workload::{App, AppBuilder, ProgramBuilder};

/// Thread allocation across the four parallel stages.
#[derive(Clone, Copy, Debug)]
pub struct FerretConfig {
    pub seg: usize,
    pub extract: usize,
    pub index: usize,
    pub rank: usize,
    /// Number of query images flowing through the pipeline.
    pub queries: u64,
}

impl Default for FerretConfig {
    fn default() -> Self {
        // The paper's default run: 15 threads per parallel stage.
        FerretConfig {
            seg: 15,
            extract: 15,
            index: 15,
            rank: 15,
            queries: 280,
        }
    }
}

impl FerretConfig {
    pub fn with_alloc(seg: usize, extract: usize, index: usize, rank: usize) -> Self {
        FerretConfig {
            seg,
            extract,
            index,
            rank,
            ..Default::default()
        }
    }

    pub fn total_threads(&self) -> usize {
        self.seg + self.extract + self.index + self.rank + 2
    }
}

/// Per-item stage costs (ns): ratio ≈ 2 : 1 : 18 : 39, matching the
/// balanced allocation the paper converged to.
const SEG_NS: u64 = 90_000;
const EXTRACT_NS: u64 = 45_000;
const INDEX_NS: u64 = 810_000;
const RANK_NS: u64 = 1_750_000;

fn split(total: u64, parts: usize) -> Vec<u64> {
    let base = total / parts as u64;
    let extra = (total % parts as u64) as usize;
    (0..parts)
        .map(|i| base + u64::from(i < extra))
        .collect()
}

pub fn ferret(seed: u64, cfg: FerretConfig) -> App {
    let mut ab = AppBuilder::new("ferret", seed);
    let q_load_seg = ab.world.new_queue(20);
    let q_seg_ext = ab.world.new_queue(20);
    let q_ext_idx = ab.world.new_queue(20);
    let q_idx_rank = ab.world.new_queue(20);
    let q_rank_out = ab.world.new_queue(20);
    let n = cfg.queries;

    // Stage 1: serial load.
    let mut load = ProgramBuilder::new(&mut ab.symtab);
    load.call("t_load", "ferret-parallel.c", 150)
        .loop_start(n)
        .compute(15_000, 0.05)
        .queue_push(q_load_seg)
        .loop_end()
        .ret();
    let prog_ = load.build();
        ab.thread("ferret-load", prog_);

    // Helper to build one parallel stage worker.
    struct Stage {
        name: &'static str,
        func: &'static str,
        line: u32,
        cost: u64,
        inner: Option<(&'static str, &'static str, u32, u64)>,
        qin: usize,
        qout: usize,
        parts: usize,
    }
    let stages = [
        Stage {
            name: "ferret-seg",
            func: "t_seg",
            line: 180,
            cost: SEG_NS,
            inner: None,
            qin: q_load_seg,
            qout: q_seg_ext,
            parts: cfg.seg,
        },
        Stage {
            name: "ferret-extract",
            func: "t_extract",
            line: 210,
            cost: EXTRACT_NS,
            inner: None,
            qin: q_seg_ext,
            qout: q_ext_idx,
            parts: cfg.extract,
        },
        Stage {
            name: "ferret-vec",
            func: "t_vec",
            line: 240,
            cost: INDEX_NS,
            inner: None,
            qin: q_ext_idx,
            qout: q_idx_rank,
            parts: cfg.index,
        },
        Stage {
            name: "ferret-rank",
            func: "t_rank",
            line: 270,
            cost: RANK_NS,
            inner: Some(("emd", "emd.c", 55, 1_400_000)),
            qin: q_idx_rank,
            qout: q_rank_out,
            parts: cfg.rank,
        },
    ];

    for st in stages {
        let shares = split(n, st.parts);
        for (i, mine) in shares.iter().enumerate() {
            let mut b = ProgramBuilder::new(&mut ab.symtab);
            b.call(st.func, "ferret-parallel.c", st.line)
                .loop_start(*mine);
            b.queue_pop(st.qin);
            match st.inner {
                Some((ifunc, ifile, iline, icost)) => {
                    // rank: outer cost wraps the hot emd/dist kernel.
                    b.call(ifunc, ifile, iline)
                        .call("dist_L2_float", "LSH_query.c", 92)
                        .compute(icost, 0.10)
                        .ret()
                        .compute(st.cost - icost, 0.10)
                        .ret();
                }
                None => {
                    b.compute(st.cost, 0.10);
                }
            }
            b.queue_push(st.qout);
            b.loop_end().ret();
            let prog: Rc<Vec<_>> = b.build();
            ab.thread(&format!("{}-{i}", st.name), prog);
        }
    }

    // Stage 6: serial output.
    let mut out = ProgramBuilder::new(&mut ab.symtab);
    out.call("t_out", "ferret-parallel.c", 300)
        .loop_start(n)
        .queue_pop(q_rank_out)
        .compute(10_000, 0.05)
        .loop_end()
        .ret();
    let prog_ = out.build();
        ab.thread("ferret-out", prog_);

    ab.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkernel::{Kernel, KernelConfig};

    fn run(cfg: FerretConfig) -> u64 {
        let app = ferret(31, cfg);
        let mut k = Kernel::new(KernelConfig::default());
        app.spawn_into(&mut k);
        k.run().unwrap()
    }

    #[test]
    fn rebalanced_allocation_halves_runtime() {
        let default = run(FerretConfig::default());
        let balanced = run(FerretConfig::with_alloc(2, 1, 18, 39));
        let gain = (default as f64 - balanced as f64) / default as f64;
        // Paper: ~50% improvement. Shape: 35%..65%.
        assert!(
            (0.35..0.65).contains(&gain),
            "default={default} balanced={balanced} gain={gain:.3}"
        );
    }

    #[test]
    fn coz_allocation_helps_less() {
        let default = run(FerretConfig::default());
        let coz = run(FerretConfig::with_alloc(20, 1, 22, 21));
        let balanced = run(FerretConfig::with_alloc(2, 1, 18, 39));
        assert!(coz < default, "coz={coz} default={default}");
        assert!(balanced < coz, "balanced={balanced} coz={coz}");
    }

    #[test]
    fn all_items_flow_through() {
        let cfg = FerretConfig {
            queries: 60,
            ..FerretConfig::with_alloc(4, 2, 4, 8)
        };
        let app = ferret(9, cfg);
        let mut k = Kernel::new(KernelConfig::default());
        app.spawn_into(&mut k);
        k.run().unwrap();
        let w = app.world.borrow();
        for q in 0..5 {
            assert_eq!(w.queues[q].total_pushed, 60, "queue {q}");
            assert_eq!(w.queues[q].tokens, 0, "queue {q}");
        }
    }
}
