//! Nektar++ IncNSS (Incompressible Navier–Stokes Solver) over MPI — the
//! paper's Figure-5/6 case study.
//!
//! `ranks` MPI processes each own a mesh partition; every timestep they
//! run the elemental matrix-vector kernel `dgemv_` (BLAS, Table-2
//! critical function) plus `Vmath::Dot2`, then exchange halo data with
//! ring neighbours. Knobs reproduce the paper's three experiments:
//!
//! * **Progress mode** (Figure 5): `Aggressive` busy-spins in
//!   `opal_progress` (OpenMPI default) — every rank looks 100% active
//!   and the CMetric profile is flat, *masking* the imbalance;
//!   `Blocking` (MPICH ch3:sock) parks the receiver, exposing it.
//! * **Mesh** (Figure 5): `Cylinder` (unstructured) gives non-uniform
//!   partition weights; `Cuboid` (structured, hand-partitioned) is
//!   uniform and the CMetric flattens for the right reason.
//! * **BLAS** (Figure 6): `OpenBlas` cuts dgemv_ cost ~45%, moving the
//!   top bottleneck to `Vmath::Dot2` and improving runtime ~27%.

use crate::util::Prng;
use crate::workload::{App, AppBuilder, ProgramBuilder};

/// MPI progress mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MpiMode {
    /// OpenMPI default: spin in opal_progress while waiting.
    Aggressive,
    /// MPICH --with-device=ch3:sock: block in the kernel while waiting.
    Blocking,
}

/// Mesh/partition structure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MeshKind {
    /// Unstructured cylinder surface: non-uniform partitions (±35%).
    Cylinder,
    /// Structured cuboid, uniformly partitioned by hand.
    Cuboid,
}

/// BLAS implementation linked into the solver.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlasImpl {
    /// Reference netlib BLAS.
    Reference,
    /// OpenBLAS: optimized dgemv_.
    OpenBlas,
}

#[derive(Clone, Copy, Debug)]
pub struct NektarConfig {
    pub ranks: usize,
    pub mode: MpiMode,
    pub mesh: MeshKind,
    pub blas: BlasImpl,
    pub timesteps: u64,
}

impl Default for NektarConfig {
    fn default() -> Self {
        NektarConfig {
            ranks: 16,
            mode: MpiMode::Blocking,
            mesh: MeshKind::Cylinder,
            blas: BlasImpl::Reference,
            timesteps: 40,
        }
    }
}

/// Base per-timestep dgemv_ cost for an average partition (ns).
const DGEMV_NS: f64 = 1_500_000.0;
/// Vmath::Dot2 cost relative to dgemv (reference BLAS).
const DOT2_FRAC: f64 = 0.40;
/// OpenBLAS dgemv speedup factor.
const OPENBLAS_FACTOR: f64 = 0.55;
/// Busy-poll granularity in opal_progress (ns).
const POLL_NS: u64 = 2_000;

/// Partition weights per rank for a mesh kind (deterministic per seed).
pub fn partition_weights(mesh: MeshKind, ranks: usize, seed: u64) -> Vec<f64> {
    match mesh {
        MeshKind::Cuboid => vec![1.0; ranks],
        MeshKind::Cylinder => {
            let mut rng = Prng::new(seed ^ 0x4E4B);
            (0..ranks).map(|_| 0.65 + 0.7 * rng.f64()).collect()
        }
    }
}

pub fn nektar(seed: u64, cfg: NektarConfig) -> App {
    let mut ab = AppBuilder::new("nektar", seed);
    let weights = partition_weights(cfg.mesh, cfg.ranks, seed);
    let blas_factor = match cfg.blas {
        BlasImpl::Reference => 1.0,
        BlasImpl::OpenBlas => OPENBLAS_FACTOR,
    };

    // Ring halo-exchange channels: ch[r] carries messages INTO rank r
    // from each neighbour (one channel per (src → dst) direction).
    let mut ch_from_left = Vec::new(); // ch_from_left[r]: (r-1) -> r
    let mut ch_from_right = Vec::new(); // ch_from_right[r]: (r+1) -> r
    for _ in 0..cfg.ranks {
        ch_from_left.push(ab.world.new_channel());
        ch_from_right.push(ab.world.new_channel());
    }

    let spin = cfg.mode == MpiMode::Aggressive;
    for r in 0..cfg.ranks {
        let left = (r + cfg.ranks - 1) % cfg.ranks;
        let right = (r + 1) % cfg.ranks;
        let w = weights[r];
        let mut b = ProgramBuilder::new(&mut ab.symtab);
        b.call("IncNavierStokesSolver", "IncNavierStokesSolver.cpp", 90)
            .loop_start(cfg.timesteps);
        // Elemental operator evaluation: dgemv_ is the hot kernel.
        b.call("GlobalLinSysIterative::DoMatrixMultiply", "GlobalLinSysIterative.cpp", 230)
            .call("dgemv_", "libblas", 1)
            .compute((DGEMV_NS * w * blas_factor) as u64, 0.05)
            .ret()
            .call("Vmath::Dot2", "Vmath.cpp", 1070)
            .compute((DGEMV_NS * DOT2_FRAC * w) as u64, 0.05)
            .ret()
            .ret();
        // Halo exchange: send to both neighbours, then receive from both.
        b.call("MPI_Sendrecv", "libmpi", 1)
            .send(ch_from_left[right]) // we are `right`'s left neighbour
            .send(ch_from_right[left]) // we are `left`'s right neighbour
            .call("opal_progress", "opal_progress.c", 180)
            .recv(ch_from_left[r], spin, POLL_NS)
            .recv(ch_from_right[r], spin, POLL_NS)
            .ret()
            .ret();
        b.loop_end().ret();
        let prog_ = b.build();
        ab.thread(&format!("IncNSS-{r}"), prog_);
    }

    ab.finish()
}

/// Run once (no profiler) and return (runtime_ns, per-rank cpu_time).
pub fn run_nektar(seed: u64, cfg: NektarConfig) -> (u64, Vec<u64>) {
    use crate::simkernel::{Kernel, KernelConfig};
    let app = nektar(seed, cfg);
    let mut k = Kernel::new(KernelConfig::default());
    let pids = app.spawn_into(&mut k);
    let end = k.run().expect("nektar run");
    let cpu = pids
        .iter()
        .map(|p| k.task(*p).unwrap().cpu_time)
        .collect();
    (end, cpu)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Summary;

    #[test]
    fn aggressive_mode_masks_imbalance_in_cpu_time() {
        let (_, cpu_spin) = run_nektar(
            7,
            NektarConfig {
                mode: MpiMode::Aggressive,
                timesteps: 10,
                ..Default::default()
            },
        );
        let (_, cpu_block) = run_nektar(
            7,
            NektarConfig {
                mode: MpiMode::Blocking,
                timesteps: 10,
                ..Default::default()
            },
        );
        let cv = |xs: &[u64]| {
            Summary::of(&xs.iter().map(|x| *x as f64).collect::<Vec<_>>()).cv()
        };
        // Spinning ranks all burn CPU until the slowest finishes: flat.
        // Blocking ranks' CPU time tracks their partition weight: spread.
        assert!(
            cv(&cpu_spin) < 0.5 * cv(&cpu_block),
            "cv_spin={:.3} cv_block={:.3}",
            cv(&cpu_spin),
            cv(&cpu_block)
        );
    }

    #[test]
    fn structured_mesh_flattens_load() {
        let (_, cyl) = run_nektar(
            7,
            NektarConfig {
                timesteps: 10,
                ..Default::default()
            },
        );
        let (_, cub) = run_nektar(
            7,
            NektarConfig {
                mesh: MeshKind::Cuboid,
                ranks: 8,
                timesteps: 10,
                ..Default::default()
            },
        );
        let cv = |xs: &[u64]| {
            Summary::of(&xs.iter().map(|x| *x as f64).collect::<Vec<_>>()).cv()
        };
        assert!(cv(&cub) < 0.05, "cv_cuboid={:.3}", cv(&cub));
        assert!(cv(&cyl) > 0.10, "cv_cylinder={:.3}", cv(&cyl));
    }

    #[test]
    fn openblas_improves_runtime_about_27pct() {
        let (base, _) = run_nektar(7, NektarConfig::default());
        let (fast, _) = run_nektar(
            7,
            NektarConfig {
                blas: BlasImpl::OpenBlas,
                ..Default::default()
            },
        );
        let gain = (base as f64 - fast as f64) / base as f64;
        // Paper: 27%. Shape: 15%..40%.
        assert!((0.15..0.40).contains(&gain), "gain={gain:.3}");
    }
}
