//! Vips: image-processing pipeline (libvips-style fused operations).
//!
//! Worker threads pull tile work from a shared queue guarded by a pool
//! lock; the colour-space conversion `imb_LabQ2Lab` (Table-2 critical
//! function) dominates tile cost. Serialization comes from the work-pool
//! lock plus the single-threaded image writeback that drains completed
//! tiles. CR ≈ 3.2% in the paper.

use crate::workload::{App, AppBuilder, ProgramBuilder};

pub fn vips(threads: usize, seed: u64) -> App {
    let mut ab = AppBuilder::new("vips", seed);
    let tiles = ab.world.new_queue(64);
    let done_q = ab.world.new_queue(usize::MAX >> 1);
    let pool_lock = ab.world.new_mutex();

    let total_tiles: u64 = 600;
    let per = total_tiles / threads as u64;
    let extra = (total_tiles % threads as u64) as usize;

    // Main thread: generates tile descriptors (cheap), then drains
    // completed tiles and writes the output image (serial).
    let mut m = ProgramBuilder::new(&mut ab.symtab);
    m.call("main", "vips.c", 90)
        .loop_start(total_tiles)
        .compute(2_000, 0.05) // demand-generate a tile descriptor
        .queue_push(tiles)
        .loop_end();
    m.call("write_vips", "vips.c", 300)
        .loop_start(total_tiles)
        .queue_pop(done_q)
        .compute(25_000, 0.08) // serial writeback per tile
        .loop_end()
        .ret()
        .ret();
    let prog_ = m.build();
        ab.thread("vips", prog_);

    for i in 0..threads {
        let mine = per + u64::from(i < extra);
        let mut b = ProgramBuilder::new(&mut ab.symtab);
        b.call("vips_thread_main_loop", "threadpool.c", 120)
            .loop_start(mine);
        // Fetch work under the pool lock (short, moderately contended).
        b.lock(pool_lock).compute(3_000, 0.1).unlock(pool_lock);
        b.queue_pop(tiles);
        // Process the tile: LabQ→Lab conversion dominates.
        b.call("imb_LabQ2Lab", "LabQ2Lab.c", 64)
            .compute(160_000, 0.12)
            .ret();
        b.call("imb_XYZ2Lab", "XYZ2Lab.c", 110)
            .compute(40_000, 0.10)
            .ret();
        b.queue_push(done_q);
        b.loop_end().ret();
        let prog_ = b.build();
        ab.thread(&format!("vips-w{i}"), prog_);
    }

    ab.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkernel::{Kernel, KernelConfig};

    #[test]
    fn all_tiles_processed() {
        let app = vips(8, 13);
        let mut k = Kernel::new(KernelConfig::default());
        app.spawn_into(&mut k);
        let end = k.run().unwrap();
        let w = app.world.borrow();
        assert_eq!(w.queues[0].total_pushed, 600);
        assert_eq!(w.queues[1].total_pushed, 600);
        // Serial writeback floor: 600 × 25 µs.
        assert!(end >= 15_000_000, "end={end}");
    }
}
