//! MySQL 5.7 running sysbench OLTP_Read_Write — the paper's Figure-7
//! case study.
//!
//! Client worker threads execute transactions against InnoDB:
//!
//! * every transaction reads the index under an InnoDB rwlock acquired
//!   via the spin-then-park path (`rw_lock_s_lock_spin` →
//!   `sync_array_reserve_cell`, Figure 7b) — spin rounds =
//!   `INNODB_SPIN_WAIT_DELAY`;
//! * write transactions take the rwlock exclusively;
//! * commits flush the redo log through `fil_flush` →
//!   `pfs_os_file_flush_func` (Figure 7a) under the log mutex — a
//!   *serial I/O section* whose frequency depends on the buffer-pool
//!   size (small pool → flush storms; 90 GB pool → group commit).
//!
//! Reproduced tuning results (§5.3): buffer pool 90 GB → +19% tps /
//! −16% latency; then spin delay 6 → 30 → +34% cumulative tps; spin
//! delay alone (without the buffer-pool fix) ≈ no effect — the paper's
//! argument for fixing bottlenecks in criticality order.

use crate::workload::{App, AppBuilder, ProgramBuilder};

/// InnoDB tuning knobs (paper §5.3).
#[derive(Clone, Copy, Debug)]
pub struct MysqlConfig {
    /// innodb_buffer_pool_size, GB. Default 8 (small); tuned run: 90.
    pub buffer_pool_gb: u32,
    /// INNODB_SPIN_WAIT_DELAY. Default 6; tuned run: 30.
    pub spin_wait_delay: u32,
    /// Transactions per client thread.
    pub txns_per_client: u64,
}

impl Default for MysqlConfig {
    fn default() -> Self {
        MysqlConfig {
            buffer_pool_gb: 8,
            spin_wait_delay: 6,
            txns_per_client: 120,
        }
    }
}

/// One spin round's cost inside sync_array_reserve_cell (ns).
const SPIN_ROUND_NS: u64 = 700;

pub fn mysql(threads: usize, seed: u64, cfg: MysqlConfig) -> App {
    let mut ab = AppBuilder::new("mysql", seed);
    let index_rw = ab.world.new_rwlock();
    let log_mutex = ab.world.new_mutex();

    // Buffer-pool model: a small pool forces a synchronous flush on
    // (nearly) every commit; a large pool absorbs dirty pages so only
    // every k-th commit flushes (group commit), and each flush is
    // cheaper because neighbouring pages coalesce. The amortized serial
    // time per commit is flush_ns / flush_every.
    let big_pool = cfg.buffer_pool_gb >= 64;
    let flush_every: u64 = if big_pool { 8 } else { 1 };
    let flush_ns: u64 = if big_pool { 20_000 } else { 8_000 };

    for i in 0..threads {
        let mut b = ProgramBuilder::new(&mut ab.symtab);
        b.call("pfs_spawn_thread", "pfs.cc", 2190)
            .call("handle_connection", "connection_handler_per_thread.cc", 300)
            .loop_start(cfg.txns_per_client);
        b.txn_start();
        // Parse + optimize + execute (row reads from the buffer pool).
        b.call("mysql_execute_command", "sql_parse.cc", 2700)
            .compute(160_000, 0.20)
            .ret();
        // Index access under the InnoDB rwlock: spin then park.
        let write_txn = i % 5 == 0; // 20% of clients are write-heavy
        b.call("btr_cur_search_to_nth_level", "btr0cur.cc", 1100)
            .call("rw_lock_s_lock_spin", "sync0rw.cc", 370)
            .call("sync_array_reserve_cell", "sync0arr.cc", 350)
            .rw_lock(
                index_rw,
                write_txn,
                cfg.spin_wait_delay,
                SPIN_ROUND_NS,
            )
            .ret()
            .ret()
            .compute(if write_txn { 14_000 } else { 30_000 }, 0.15)
            .rw_unlock(index_rw, write_txn)
            .ret();
        // Commit: redo-log flush under the log mutex (serial I/O). The
        // group-commit factor amortizes the flush cost across commits:
        // every commit pays flush_ns / flush_every of serialized I/O.
        b.call("trx_commit_complete_for_mysql", "trx0trx.cc", 1900);
        b.lock(log_mutex)
            .call("fil_flush", "fil0fil.cc", 5350)
            .call("pfs_os_file_flush_func", "os0file.ic", 450)
            .sleep(flush_ns / flush_every, 0.25)
            .ret()
            .ret()
            .unlock(log_mutex);
        b.ret();
        b.txn_end();
        b.loop_end().ret().ret();
        let prog_ = b.build();
        ab.thread(&format!("mysqld-{i}"), prog_);
    }

    ab.finish()
}

/// Throughput/latency outcome of one simulated sysbench run.
#[derive(Clone, Copy, Debug)]
pub struct OltpOutcome {
    pub tps: f64,
    pub avg_latency_ns: f64,
    pub txns: u64,
}

/// Run the workload (no profiler) and report sysbench-style metrics.
pub fn run_oltp(threads: usize, seed: u64, cfg: MysqlConfig) -> OltpOutcome {
    use crate::simkernel::{Kernel, KernelConfig};
    let app = mysql(threads, seed, cfg);
    let mut k = Kernel::new(KernelConfig::default());
    app.spawn_into(&mut k);
    let end = k.run().expect("mysql run");
    let w = app.world.borrow();
    let txns = w.latencies.len() as u64;
    let avg = if txns > 0 {
        w.latencies.iter().sum::<u64>() as f64 / txns as f64
    } else {
        0.0
    };
    OltpOutcome {
        tps: txns as f64 / (end as f64 / 1e9),
        avg_latency_ns: avg,
        txns,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_pool_tuning_raises_tps() {
        let base = run_oltp(32, 41, MysqlConfig::default());
        let tuned = run_oltp(
            32,
            41,
            MysqlConfig {
                buffer_pool_gb: 90,
                ..Default::default()
            },
        );
        let gain = (tuned.tps - base.tps) / base.tps;
        // Paper: +19% tps. Shape: 8%..45%.
        assert!(
            (0.08..0.45).contains(&gain),
            "base={:.0} tuned={:.0} gain={gain:.3}",
            base.tps,
            tuned.tps
        );
        assert!(tuned.avg_latency_ns < base.avg_latency_ns);
    }

    #[test]
    fn spin_delay_alone_is_negligible() {
        // §5.3: "optimising the spin-wait delay without first optimising
        // the buffer size made negligible difference".
        let base = run_oltp(32, 41, MysqlConfig::default());
        let spun = run_oltp(
            32,
            41,
            MysqlConfig {
                spin_wait_delay: 30,
                ..Default::default()
            },
        );
        let delta = ((spun.tps - base.tps) / base.tps).abs();
        assert!(delta < 0.08, "delta={delta:.3}");
    }

    #[test]
    fn cumulative_tuning_beats_buffer_alone() {
        let buffer = run_oltp(
            32,
            41,
            MysqlConfig {
                buffer_pool_gb: 90,
                ..Default::default()
            },
        );
        let both = run_oltp(
            32,
            41,
            MysqlConfig {
                buffer_pool_gb: 90,
                spin_wait_delay: 30,
                ..Default::default()
            },
        );
        assert!(
            both.tps > buffer.tps,
            "both={:.0} buffer={:.0}",
            both.tps,
            buffer.tps
        );
    }

    #[test]
    fn all_transactions_complete() {
        let out = run_oltp(8, 5, MysqlConfig {
            txns_per_client: 20,
            ..Default::default()
        });
        assert_eq!(out.txns, 8 * 20);
    }
}
