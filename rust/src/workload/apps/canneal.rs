//! Canneal: simulated-annealing netlist routing.
//!
//! Workers evaluate `netlist_elem::swap_cost` (Table-2 critical function)
//! in a tight loop; element swaps occasionally touch a shared lock with
//! *low* contention — the §6.1 limitation case (low-contention locks may
//! not be flagged). CR is tiny (paper: 0.06%).

use crate::workload::{App, AppBuilder, ProgramBuilder};

pub fn canneal(threads: usize, seed: u64) -> App {
    let mut ab = AppBuilder::new("canneal", seed);
    let done = ab.world.new_latch(threads as u64);
    let swap_lock = ab.world.new_mutex();

    for i in 0..threads {
        let _ = i;
        let mut b = ProgramBuilder::new(&mut ab.symtab);
        b.call("annealer_thread", "annealer_thread.cpp", 60)
            .loop_start(12);
        // Evaluate a batch of swap costs (hot), then one short critical
        // section to commit accepted swaps — low-contention by design
        // (the §6.1 limitation case).
        b.loop_start(8)
            .call("netlist_elem::swap_cost", "netlist_elem.cpp", 86)
            .compute(28_000, 0.10)
            .ret()
            .loop_end();
        b.lock(swap_lock)
            .compute(900, 0.1)
            .unlock(swap_lock);
        b.loop_end().latch_signal(done).ret();
        let prog_ = b.build();
        ab.thread(&format!("anneal-{i}"), prog_);
    }

    let mut m = ProgramBuilder::new(&mut ab.symtab);
    m.call("main", "main.cpp", 150)
        .compute(1_200_000, 0.02) // netlist load (serial)
        .latch_wait(done)
        .compute(400_000, 0.02) // final routing cost (serial)
        .ret();
    let prog_ = m.build();
        ab.thread("canneal", prog_);

    ab.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkernel::{Kernel, KernelConfig};

    #[test]
    fn lock_contention_is_low() {
        let app = canneal(16, 3);
        let mut k = Kernel::new(KernelConfig::default());
        app.spawn_into(&mut k);
        k.run().unwrap();
        let w = app.world.borrow();
        let m = &w.mutexes[0];
        assert!(m.acquisitions > 0);
        // Short holds over many CPUs: contention well under 50%.
        assert!(
            (m.contended as f64) < 0.5 * m.acquisitions as f64,
            "contended={} acq={}",
            m.contended,
            m.acquisitions
        );
    }
}
