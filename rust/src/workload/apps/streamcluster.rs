//! Streamcluster: online clustering, barrier-heavy.
//!
//! The paper's Table 2 lists `parsec_barrier_wait` *and* `dist` as the
//! critical functions and the highest critical-slice count of the suite
//! (CR ≈ 10.6%, 2.2M timeslices): the algorithm alternates very short
//! `dist()` evaluation phases with barriers many times per iteration, so
//! threads cross the low-parallelism boundary constantly.

use crate::util::Prng;
use crate::workload::{App, AppBuilder, ProgramBuilder};

pub fn streamcluster(threads: usize, seed: u64) -> App {
    let mut ab = AppBuilder::new("streamcluster", seed);
    let bar = ab.world.new_barrier(threads);
    let mut rng = Prng::new(seed ^ 0x5C);

    let weights: Vec<f64> = (0..threads)
        .map(|_| 1.0 + 0.3 * (rng.f64() - 0.5))
        .collect();

    for (i, w) in weights.iter().enumerate() {
        let mut b = ProgramBuilder::new(&mut ab.symtab);
        b.call("localSearchSub", "streamcluster.cpp", 1750)
            .loop_start(120); // pgain iterations
        // Phase 1: distance evaluation sweep.
        b.call("dist", "streamcluster.cpp", 160)
            .compute((280_000.0 * w) as u64, 0.10)
            .ret();
        b.call("parsec_barrier_wait", "parsec_barrier.c", 80)
            .barrier(bar)
            .ret();
        // Phase 2: cost accumulation — thread 0 carries a serial section
        // (center opening decision) while the team waits again.
        if i == 0 {
            b.call("pgain", "streamcluster.cpp", 1000)
                .compute(150_000, 0.08)
                .ret();
        } else {
            b.call("pgain", "streamcluster.cpp", 1000)
                .compute((40_000.0 * w) as u64, 0.10)
                .ret();
        }
        b.call("parsec_barrier_wait", "parsec_barrier.c", 80)
            .barrier(bar)
            .ret();
        b.loop_end().ret();
        let prog_ = b.build();
        ab.thread(&format!("stream-{i}"), prog_);
    }

    ab.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkernel::{Kernel, KernelConfig};

    #[test]
    fn many_barrier_crossings() {
        let app = streamcluster(8, 4);
        let mut k = Kernel::new(KernelConfig::default());
        app.spawn_into(&mut k);
        let end = k.run().unwrap();
        assert_eq!(app.world.borrow().barriers[0].generation, 240);
        // Serial pgain on thread 0 stretches every iteration.
        assert!(end >= 120 * (280_000 + 150_000), "end={end}");
    }
}
