//! Facesim: physics simulation of a human face, iterative with
//! fork-join phases.
//!
//! Each frame runs a parallel `Update_Position_Based_State_Helper`
//! (Table-2 critical function) over statically-partitioned mesh regions
//! whose sizes are *not* uniform — the thread owning the densest region
//! finishes last and is sampled with low parallelism while the rest wait
//! at the frame barrier. CR is very small (paper: 0.004%).

use crate::util::Prng;
use crate::workload::{App, AppBuilder, ProgramBuilder};

pub fn facesim(threads: usize, seed: u64) -> App {
    let mut ab = AppBuilder::new("facesim", seed);
    let frame_barrier = ab.world.new_barrier(threads);
    let mut rng = Prng::new(seed ^ 0xFACE);

    // Static region weights: mostly ~1.0, one hot region ~1.5.
    let mut weights: Vec<f64> = (0..threads)
        .map(|_| 1.0 + 0.12 * (rng.f64() - 0.5))
        .collect();
    weights[0] = 1.5;

    for (i, w) in weights.iter().enumerate() {
        let mut b = ProgramBuilder::new(&mut ab.symtab);
        b.call("TaskQ_worker", "taskq.c", 30).loop_start(24); // frames
        b.call(
            "Update_Position_Based_State_Helper",
            "FACE_EXAMPLE.h",
            420,
        )
        .compute((2_200_000.0 * w) as u64, 0.05)
        .ret();
        b.call("parsec_barrier_wait", "parsec_barrier.c", 80)
            .barrier(frame_barrier)
            .ret();
        b.loop_end().ret();
        let prog_ = b.build();
        ab.thread(&format!("facesim-{i}"), prog_);
    }

    ab.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkernel::{Kernel, KernelConfig};

    #[test]
    fn slowest_region_bounds_frame_time() {
        let app = facesim(16, 9);
        let mut k = Kernel::new(KernelConfig::default());
        app.spawn_into(&mut k);
        let end = k.run().unwrap();
        // 24 frames × ~(1.5 × 2.2 ms) for the hot region.
        assert!(end >= 24 * 3_000_000, "end={end}");
        // The hot thread has the most CPU time.
        let hottest = k
            .all_tasks()
            .max_by_key(|t| t.cpu_time)
            .unwrap()
            .comm
            .clone();
        assert_eq!(hottest, "facesim-0");
    }
}
