//! Bodytrack: per-frame command/response between a parent and a worker
//! pool — the paper's Figure-3 case study.
//!
//! Structure (paper §5.2): the parent broadcasts a per-frame command;
//! workers process the frame (`ProcessFrame` via `ExecCmd`) and wait for
//! the next command in `RecvCmd()` (condition-variable wait). The parent
//! then runs `OutputBMP()` *serially* while every worker sits in
//! RecvCmd — that serial section is the previously-unreported bottleneck
//! GAPP found. Two knobs reproduce the paper's two interventions:
//!
//! * `skip_output` — "comment out OutputBMP": RecvCmd samples drop ~45%.
//! * `offload_writer` — move OutputBMP to a dedicated writerThread fed by
//!   a queue (Figure 3 right): ~22% faster end-to-end.

use crate::workload::{App, AppBuilder, ProgramBuilder};

/// Experiment knobs for the Figure-3 study.
#[derive(Clone, Copy, Debug, Default)]
pub struct BodytrackConfig {
    /// Offload OutputBMP to a writer thread (the paper's fix).
    pub offload_writer: bool,
    /// Comment out OutputBMP entirely (the paper's confirmation run).
    pub skip_output: bool,
}

pub const FRAMES: u64 = 40;
/// Per-worker frame processing cost (ns).
pub const FRAME_WORK_NS: u64 = 4_000_000;
/// Serial OutputBMP cost per frame (ns).
pub const OUTPUT_BMP_NS: u64 = 1_150_000;

/// Build bodytrack with `threads` workers (+ parent, + optional writer).
pub fn bodytrack(threads: usize, seed: u64, cfg: BodytrackConfig) -> App {
    let mut ab = AppBuilder::new("bodytrack", seed);
    // Command distribution: the parent pushes one command token per
    // worker per frame; workers wait in RecvCmd with a backoff-polling
    // loop (check, sleep, re-check) — which is why RecvCmd shows up in
    // IP samples in proportion to the time workers spend waiting, and
    // why removing OutputBMP cut RecvCmd samples ~45% in the paper.
    let cmd_queue = ab.world.new_queue(usize::MAX >> 1);
    let done_barrier = ab.world.new_barrier(threads + 1);
    let bmp_queue = ab.world.new_queue(8);

    for i in 0..threads {
        let mut b = ProgramBuilder::new(&mut ab.symtab);
        b.call("WorkerThread::Run", "WorkerThread.cpp", 150)
            .loop_start(FRAMES);
        // Wait for the parent's command (the paper's RecvCmd wait).
        b.call("condition_variable::RecvCmd", "WorkerThread.cpp", 78)
            .queue_poll_pop(cmd_queue, 25_000, 280_000)
            .ret();
        b.call("ExecCmd", "WorkerThread.cpp", 101)
            .call("ParticleFilter::Update", "ParticleFilter.h", 330)
            .compute(FRAME_WORK_NS, 0.08)
            .ret()
            .ret();
        // Signal frame completion back to the parent.
        b.call("condition_variable::NotifyDone", "WorkerThread.cpp", 92)
            .barrier(done_barrier)
            .ret();
        b.loop_end().ret();
        let prog_ = b.build();
        ab.thread(&format!("bodytrack-w{i}"), prog_);
    }

    // Parent thread.
    let mut p = ProgramBuilder::new(&mut ab.symtab);
    p.call("mainPthreads", "main.cpp", 250).loop_start(FRAMES);
    p.compute(120_000, 0.05); // per-frame setup / command preparation
    p.call("TrackingModelPthread::SendCmd", "TrackingModelPthread.cpp", 60);
    for _ in 0..threads {
        p.queue_push(cmd_queue);
    }
    p.ret();
    // Workers process the frame; the parent joins the done rendezvous.
    p.barrier(done_barrier);
    if cfg.offload_writer {
        // Fix: hand the image to writerThread and move straight on.
        p.queue_push(bmp_queue);
    } else if !cfg.skip_output {
        p.call("TrackingModel::OutputBMP", "TrackingModel.cpp", 178)
            .compute(OUTPUT_BMP_NS, 0.05)
            .ret();
    }
    p.loop_end().ret();
    let prog_ = p.build();
        ab.thread("bodytrack", prog_);

    if cfg.offload_writer {
        let mut w = ProgramBuilder::new(&mut ab.symtab);
        w.call("writerThread", "main.cpp", 420).loop_start(FRAMES);
        w.queue_pop(bmp_queue);
        w.call("TrackingModel::OutputBMP", "TrackingModel.cpp", 178)
            .compute(OUTPUT_BMP_NS, 0.05)
            .ret();
        w.loop_end().ret();
        let prog_ = w.build();
        ab.thread("writerThread", prog_);
    }

    ab.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkernel::{Kernel, KernelConfig};

    fn run(cfg: BodytrackConfig) -> u64 {
        let app = bodytrack(16, 21, cfg);
        let mut k = Kernel::new(KernelConfig::default());
        app.spawn_into(&mut k);
        k.run().unwrap()
    }

    #[test]
    fn writer_offload_speeds_up_like_figure3() {
        let base = run(BodytrackConfig::default());
        let fixed = run(BodytrackConfig {
            offload_writer: true,
            ..Default::default()
        });
        let gain = (base as f64 - fixed as f64) / base as f64;
        // Paper: 22% improvement. Shape check: 10%..35%.
        assert!(
            (0.10..0.35).contains(&gain),
            "base={base} fixed={fixed} gain={gain:.3}"
        );
    }

    #[test]
    fn skip_output_removes_serial_section() {
        let base = run(BodytrackConfig::default());
        let skipped = run(BodytrackConfig {
            skip_output: true,
            ..Default::default()
        });
        assert!(skipped < base);
    }
}
