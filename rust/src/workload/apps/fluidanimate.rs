//! Fluidanimate: SPH fluid simulation with many barrier-separated phases.
//!
//! The per-frame work is split into several short phases, each ending at
//! `parsec_barrier_wait` (Table-2 critical function). Mild per-thread
//! imbalance makes the barrier the dominant wait site: the last arrivals
//! execute with low parallelism and everyone else is parked inside the
//! barrier — exactly the signature GAPP attributes to
//! `parsec_barrier_wait`. CR ≈ 1% in the paper.

use crate::util::Prng;
use crate::workload::{App, AppBuilder, ProgramBuilder};

pub fn fluidanimate(threads: usize, seed: u64) -> App {
    let mut ab = AppBuilder::new("fluidanimate", seed);
    let bar = ab.world.new_barrier(threads);
    let mut rng = Prng::new(seed ^ 0xF1D);

    // Grid-cell partitions: ±12% load spread, fixed per thread.
    let weights: Vec<f64> = (0..threads)
        .map(|_| 1.0 + 0.24 * (rng.f64() - 0.5))
        .collect();

    const PHASES: [(&str, u64, u32); 5] = [
        ("ComputeForcesMT", 900_000, 410),
        ("ComputeDensitiesMT", 700_000, 290),
        ("AdvanceParticlesMT", 350_000, 520),
        ("RebuildGridMT", 250_000, 180),
        ("ClearParticlesMT", 120_000, 120),
    ];

    for (i, w) in weights.iter().enumerate() {
        let mut b = ProgramBuilder::new(&mut ab.symtab);
        b.call("AdvanceFramesMT", "pthreads.cpp", 1050)
            .loop_start(20); // frames
        for (func, cost, line) in PHASES {
            b.call(func, "pthreads.cpp", line)
                .compute((cost as f64 * w) as u64, 0.08)
                .ret();
            b.call("parsec_barrier_wait", "parsec_barrier.c", 80)
                .barrier(bar)
                .ret();
        }
        b.loop_end().ret();
        let prog_ = b.build();
        ab.thread(&format!("fluid-{i}"), prog_);
    }

    ab.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkernel::{Kernel, KernelConfig};

    #[test]
    fn barriers_gate_every_phase() {
        let app = fluidanimate(8, 2);
        let mut k = Kernel::new(KernelConfig::default());
        app.spawn_into(&mut k);
        let end = k.run().unwrap();
        // 20 frames × 5 phases, each bounded below by the base phase cost.
        assert!(end >= 20 * (900_000 + 700_000 + 350_000 + 250_000 + 120_000));
        let gens = app.world.borrow().barriers[0].generation;
        assert_eq!(gens, 20 * 5);
    }
}
