//! The 13 synthetic applications of Table 2 (11 × Parsec 3.0 + MySQL +
//! Nektar++). Each constructor returns an [`crate::workload::App`] whose
//! thread programs reproduce the *structure* that creates the published
//! bottleneck; knobs mirror the paper's tuning experiments.

mod blackscholes;
mod bodytrack;
mod canneal;
mod dedup;
mod facesim;
mod ferret;
mod fluidanimate;
mod freqmine;
mod mysql;
mod nektar;
mod streamcluster;
mod swaptions;
mod vips;

pub use blackscholes::blackscholes;
pub use bodytrack::{bodytrack, BodytrackConfig};
pub use canneal::canneal;
pub use dedup::{dedup, DedupConfig};
pub use facesim::facesim;
pub use ferret::{ferret, FerretConfig};
pub use fluidanimate::fluidanimate;
pub use freqmine::freqmine;
pub use mysql::{mysql, run_oltp, MysqlConfig, OltpOutcome};
pub use nektar::{
    nektar, partition_weights, run_nektar, BlasImpl, MeshKind, MpiMode, NektarConfig,
};
pub use streamcluster::streamcluster;
pub use swaptions::swaptions;
pub use vips::vips;

use crate::workload::App;

/// Scale factor applied to all workload sizes (1.0 ≈ a few hundred ms of
/// simulated runtime per app; the paper's native inputs run tens of
/// seconds — shape is preserved, constants are scaled for CI).
pub const DEFAULT_SCALE: f64 = 1.0;

/// Construct a Table-2 application by name with default configuration.
pub fn by_name(name: &str, threads: usize, seed: u64) -> Option<App> {
    Some(match name {
        "blackscholes" => blackscholes(threads, seed),
        "bodytrack" => bodytrack(threads, seed, BodytrackConfig::default()),
        "canneal" => canneal(threads, seed),
        "dedup" => dedup(seed, DedupConfig::default()),
        "facesim" => facesim(threads, seed),
        "ferret" => ferret(seed, FerretConfig::default()),
        "fluidanimate" => fluidanimate(threads, seed),
        "freqmine" => freqmine(threads, seed),
        "mysql" => mysql(threads, seed, MysqlConfig::default()),
        "nektar" => nektar(seed, NektarConfig::default()),
        "streamcluster" => streamcluster(threads, seed),
        "swaptions" => swaptions(threads, seed),
        "vips" => vips(threads, seed),
        _ => return None,
    })
}

/// All Table-2 application names, in the paper's order.
pub const ALL_APPS: [&str; 13] = [
    "blackscholes",
    "bodytrack",
    "canneal",
    "dedup",
    "facesim",
    "ferret",
    "fluidanimate",
    "freqmine",
    "streamcluster",
    "swaptions",
    "vips",
    "mysql",
    "nektar",
];
