//! Blackscholes: embarrassingly data-parallel option pricing.
//!
//! Each worker prices a fixed slice of options by repeatedly calling
//! `CNDF()` (the cumulative normal distribution — the paper's Table-2
//! critical function). Serialization is limited to the initial load and
//! the final join, so the critical ratio is tiny (paper: CR = 2%,
//! overhead < 1%) and the only place low-parallelism samples can land is
//! CNDF itself, executed by the last workers to finish.

use crate::workload::{App, AppBuilder, ProgramBuilder};

/// Build blackscholes with `threads` workers (+1 main thread).
pub fn blackscholes(threads: usize, seed: u64) -> App {
    let mut ab = AppBuilder::new("blackscholes", seed);
    let done = ab.world.new_latch(threads as u64);

    // Worker: price options in a loop; CNDF dominates each iteration.
    for i in 0..threads {
        let mut b = ProgramBuilder::new(&mut ab.symtab);
        b.call("bs_thread", "blackscholes.c", 350)
            .loop_start(120)
            .call("BlkSchlsEqEuroNoDiv", "blackscholes.c", 240)
            .call("CNDF", "blackscholes.c", 110)
            .compute(22_000, 0.06)
            .ret()
            .compute(6_000, 0.05)
            .ret()
            .loop_end()
            .latch_signal(done)
            .ret();
        let prog_ = b.build();
        ab.thread(&format!("bs-{i}"), prog_);
    }

    // Main: sequential input parse, then join, then sequential output.
    let mut m = ProgramBuilder::new(&mut ab.symtab);
    m.call("main", "blackscholes.c", 400)
        .compute(2_000_000, 0.02) // read input (serial)
        .latch_wait(done)
        .compute(1_500_000, 0.02) // write prices (serial)
        .ret();
    let prog_ = m.build();
        ab.thread("blackscholes", prog_);

    ab.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkernel::{Kernel, KernelConfig};

    #[test]
    fn runs_and_scales() {
        let run = |threads: usize| {
            let app = blackscholes(threads, 7);
            let mut k = Kernel::new(KernelConfig::default());
            app.spawn_into(&mut k);
            k.run().unwrap()
        };
        let t8 = run(8);
        let t32 = run(32);
        // More workers → shorter runtime (slice per worker is fixed, so
        // the parallel phase is constant; check at least non-inflation).
        assert!(t32 <= t8 + 1_000_000, "t8={t8} t32={t32}");
    }

    #[test]
    fn worker_count_matches() {
        let app = blackscholes(64, 1);
        assert_eq!(app.num_threads(), 65);
    }
}
