//! Synthetic parallel-application substrate.
//!
//! The paper evaluates GAPP on Parsec 3.0, MySQL and Nektar++ — none of
//! which can run here. Each is rebuilt as a synthetic application: an
//! op-level program per thread ([`program`]) over shared synchronization
//! objects ([`world`]) with a synthetic binary image ([`symbols`]) so
//! samples resolve to functions and source lines. The *structure that
//! creates each bottleneck* (pipeline shapes, serial phases, spin loops,
//! lock protocols, partition imbalance) is reproduced from the paper's
//! description, so GAPP's detections emerge from mechanism.
//!
//! [`apps`] contains the 13 applications of Table 2.

pub mod symbols;
pub mod world;
pub mod program;
pub mod apps;

use std::cell::RefCell;
use std::rc::Rc;

use crate::simkernel::{Kernel, Pid};
use crate::util::Prng;

pub use program::{Inst, Op, ProgramBuilder, ThreadLogic};
pub use symbols::{Location, SymId, SymbolTable};
pub use world::{ObjId, World};

/// A fully-assembled synthetic application ready to load into a kernel.
pub struct App {
    pub name: String,
    pub symtab: Rc<SymbolTable>,
    pub world: Rc<RefCell<World>>,
    /// (comm, program) per thread, spawn order preserved.
    pub threads: Vec<(String, Rc<Vec<Inst>>)>,
    pub seed: u64,
}

impl App {
    /// Spawn every thread into `k` (tracking all of them) and return pids.
    pub fn spawn_into(&self, k: &mut Kernel) -> Vec<Pid> {
        let mut rng = Prng::new(self.seed);
        let mut pids = Vec::with_capacity(self.threads.len());
        for (i, (comm, prog)) in self.threads.iter().enumerate() {
            let logic = ThreadLogic::new(
                prog.clone(),
                self.world.clone(),
                self.symtab.clone(),
                rng.fork(i as u64 + 1),
            );
            let pid = k.spawn(comm, logic);
            k.track(pid);
            pids.push(pid);
        }
        pids
    }

    pub fn num_threads(&self) -> usize {
        self.threads.len()
    }
}

/// Helper for app constructors: collect built programs + shared state
/// into an [`App`].
pub struct AppBuilder {
    pub name: String,
    pub symtab: SymbolTable,
    pub world: World,
    pub threads: Vec<(String, Rc<Vec<Inst>>)>,
    pub seed: u64,
}

impl AppBuilder {
    pub fn new(name: &str, seed: u64) -> AppBuilder {
        AppBuilder {
            name: name.to_string(),
            symtab: SymbolTable::new(),
            world: World::new(),
            threads: Vec::new(),
            seed,
        }
    }

    pub fn thread(&mut self, comm: &str, prog: Rc<Vec<Inst>>) {
        self.threads.push((comm.to_string(), prog));
    }

    pub fn finish(self) -> App {
        App {
            name: self.name,
            symtab: Rc::new(self.symtab),
            world: Rc::new(RefCell::new(self.world)),
            threads: self.threads,
            seed: self.seed,
        }
    }
}
