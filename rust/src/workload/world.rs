//! Shared synchronization objects for synthetic applications.
//!
//! These model the user-space primitives the Parsec/MySQL/Nektar++
//! workloads exercise (pthread mutex/condvar/barrier, bounded pipeline
//! queues, latches, MPI point-to-point channels, and InnoDB-style
//! spin-then-block rwlocks). Blocking and waking are mediated by the
//! program interpreter, which translates "must wait" into kernel blocks —
//! exactly the futex round-trip the real primitives compile down to, and
//! the only thing GAPP observes.

use std::collections::VecDeque;

use crate::simkernel::{Pid, Time};

/// Index of a sync object within its pool.
pub type ObjId = usize;

/// pthread_mutex with direct handoff: unlock passes ownership to the
/// oldest waiter (avoids barging nondeterminism in the simulation).
#[derive(Debug, Default)]
pub struct MutexObj {
    pub holder: Option<Pid>,
    pub waiters: VecDeque<Pid>,
    /// Contention statistics (used by tests and the SyncPerf-style report).
    pub acquisitions: u64,
    pub contended: u64,
}

/// pthread_cond.
#[derive(Debug, Default)]
pub struct CondObj {
    pub waiters: VecDeque<Pid>,
}

/// pthread_barrier (reusable, generation-counted).
#[derive(Debug, Default)]
pub struct BarrierObj {
    pub parties: usize,
    pub waiting: Vec<Pid>,
    pub generation: u64,
}

/// Bounded token queue (pipeline stage connector). Tokens are counts —
/// payloads don't matter to scheduling behaviour.
#[derive(Debug, Default)]
pub struct QueueObj {
    pub capacity: usize,
    pub tokens: usize,
    pub push_waiters: VecDeque<Pid>,
    pub pop_waiters: VecDeque<Pid>,
    pub total_pushed: u64,
}

/// Count-down latch (thread join, phase completion).
#[derive(Debug, Default)]
pub struct LatchObj {
    pub count: u64,
    pub waiters: Vec<Pid>,
}

/// MPI-style point-to-point message channel (sender never blocks; the
/// receiver blocks or busy-spins depending on the MPI progress mode).
#[derive(Debug, Default)]
pub struct ChanObj {
    pub msgs: u64,
    pub recv_waiters: VecDeque<Pid>,
}

/// Reader-writer lock with InnoDB-style spin-then-block acquisition
/// (the `rw_lock_s_lock_spin` / `sync_array_reserve_cell` path of §5.3).
#[derive(Debug, Default)]
pub struct RwLockObj {
    pub writer: Option<Pid>,
    pub readers: usize,
    pub waiters: VecDeque<(Pid, bool)>, // (pid, wants_write)
    pub contended: u64,
}

/// The shared world all threads of one application see.
#[derive(Debug, Default)]
pub struct World {
    pub mutexes: Vec<MutexObj>,
    pub conds: Vec<CondObj>,
    pub barriers: Vec<BarrierObj>,
    pub queues: Vec<QueueObj>,
    pub latches: Vec<LatchObj>,
    pub channels: Vec<ChanObj>,
    pub rwlocks: Vec<RwLockObj>,
    pub flags: Vec<bool>,
    /// Transaction latencies (ns), recorded by TxnStart/TxnEnd ops.
    pub latencies: Vec<u64>,
    txn_start: std::collections::HashMap<Pid, Time>,
    /// Rwlock ownership grants handed to parked waiters at wake time
    /// (direct handoff, so a woken waiter cannot lose the lock again —
    /// and so waking a reader parked behind a writer cannot deadlock).
    rw_granted: std::collections::HashSet<(ObjId, Pid)>,
}

impl World {
    pub fn new() -> World {
        World::default()
    }

    // ---- constructors --------------------------------------------------

    pub fn new_mutex(&mut self) -> ObjId {
        self.mutexes.push(MutexObj::default());
        self.mutexes.len() - 1
    }

    pub fn new_cond(&mut self) -> ObjId {
        self.conds.push(CondObj::default());
        self.conds.len() - 1
    }

    pub fn new_barrier(&mut self, parties: usize) -> ObjId {
        self.barriers.push(BarrierObj {
            parties,
            ..Default::default()
        });
        self.barriers.len() - 1
    }

    pub fn new_queue(&mut self, capacity: usize) -> ObjId {
        self.queues.push(QueueObj {
            capacity,
            ..Default::default()
        });
        self.queues.len() - 1
    }

    pub fn new_latch(&mut self, count: u64) -> ObjId {
        self.latches.push(LatchObj {
            count,
            ..Default::default()
        });
        self.latches.len() - 1
    }

    pub fn new_channel(&mut self) -> ObjId {
        self.channels.push(ChanObj::default());
        self.channels.len() - 1
    }

    pub fn new_rwlock(&mut self) -> ObjId {
        self.rwlocks.push(RwLockObj::default());
        self.rwlocks.len() - 1
    }

    pub fn new_flag(&mut self) -> ObjId {
        self.flags.push(false);
        self.flags.len() - 1
    }

    // ---- mutex ----------------------------------------------------------

    /// Try to acquire; on failure the caller is queued and must block.
    pub fn mutex_lock(&mut self, m: ObjId, pid: Pid) -> bool {
        let mx = &mut self.mutexes[m];
        if mx.holder.is_none() {
            mx.holder = Some(pid);
            mx.acquisitions += 1;
            true
        } else {
            mx.contended += 1;
            mx.waiters.push_back(pid);
            false
        }
    }

    /// Release; hands off to the oldest waiter and returns it for waking.
    pub fn mutex_unlock(&mut self, m: ObjId, pid: Pid) -> Option<Pid> {
        let mx = &mut self.mutexes[m];
        debug_assert_eq!(mx.holder, Some(pid), "unlock by non-holder");
        match mx.waiters.pop_front() {
            Some(next) => {
                mx.holder = Some(next);
                mx.acquisitions += 1;
                Some(next)
            }
            None => {
                mx.holder = None;
                None
            }
        }
    }

    // ---- condvar ---------------------------------------------------------

    pub fn cond_enqueue(&mut self, c: ObjId, pid: Pid) {
        self.conds[c].waiters.push_back(pid);
    }

    pub fn cond_signal(&mut self, c: ObjId) -> Option<Pid> {
        self.conds[c].waiters.pop_front()
    }

    pub fn cond_broadcast(&mut self, c: ObjId) -> Vec<Pid> {
        self.conds[c].waiters.drain(..).collect()
    }

    // ---- barrier -----------------------------------------------------------

    /// Arrive at the barrier. Returns `Some(waiters)` when this arrival
    /// releases the barrier (the arriving thread does NOT appear in the
    /// returned list); `None` means the caller must block.
    pub fn barrier_arrive(&mut self, b: ObjId, pid: Pid) -> Option<Vec<Pid>> {
        let bar = &mut self.barriers[b];
        if bar.waiting.len() + 1 >= bar.parties {
            bar.generation += 1;
            Some(std::mem::take(&mut bar.waiting))
        } else {
            bar.waiting.push(pid);
            None
        }
    }

    // ---- queue --------------------------------------------------------------

    /// Try to push a token; returns waiters to wake on success, or queues
    /// the caller (retry semantics) on failure.
    pub fn queue_try_push(&mut self, q: ObjId, pid: Pid) -> Result<Option<Pid>, ()> {
        let qu = &mut self.queues[q];
        if qu.tokens < qu.capacity {
            qu.tokens += 1;
            qu.total_pushed += 1;
            Ok(qu.pop_waiters.pop_front())
        } else {
            qu.push_waiters.push_back(pid);
            Err(())
        }
    }

    pub fn queue_try_pop(&mut self, q: ObjId, pid: Pid) -> Result<Option<Pid>, ()> {
        let qu = &mut self.queues[q];
        if qu.tokens > 0 {
            qu.tokens -= 1;
            Ok(qu.push_waiters.pop_front())
        } else {
            qu.pop_waiters.push_back(pid);
            Err(())
        }
    }

    // ---- latch ------------------------------------------------------------

    /// Count down; returns all waiters when the latch opens.
    pub fn latch_signal(&mut self, l: ObjId) -> Vec<Pid> {
        let la = &mut self.latches[l];
        la.count = la.count.saturating_sub(1);
        if la.count == 0 {
            std::mem::take(&mut la.waiters)
        } else {
            Vec::new()
        }
    }

    /// Returns true if the latch is already open; otherwise queues caller.
    pub fn latch_wait(&mut self, l: ObjId, pid: Pid) -> bool {
        let la = &mut self.latches[l];
        if la.count == 0 {
            true
        } else {
            la.waiters.push(pid);
            false
        }
    }

    // ---- channel ----------------------------------------------------------

    /// Post a message; returns a blocked receiver to wake, if any.
    pub fn chan_send(&mut self, ch: ObjId) -> Option<Pid> {
        let c = &mut self.channels[ch];
        c.msgs += 1;
        c.recv_waiters.pop_front()
    }

    /// Try to consume a message (true on success). On failure the caller
    /// either blocks (queued here) or busy-spins (not queued).
    pub fn chan_try_recv(&mut self, ch: ObjId, pid: Pid, queue_on_fail: bool) -> bool {
        let c = &mut self.channels[ch];
        if c.msgs > 0 {
            c.msgs -= 1;
            true
        } else {
            if queue_on_fail {
                c.recv_waiters.push_back(pid);
            }
            false
        }
    }

    // ---- rwlock -------------------------------------------------------------

    /// Try to acquire (read or write). No queuing here — the interpreter
    /// implements the InnoDB spin loop and calls [`World::rw_enqueue`]
    /// when it gives up spinning. Writer-preferring: a parked writer
    /// blocks new readers from barging (InnoDB's SX-latch fairness), so
    /// aggressive reader spinning cannot starve writers.
    pub fn rw_try(&mut self, rw: ObjId, pid: Pid, write: bool) -> bool {
        if self.rw_granted.remove(&(rw, pid)) {
            return true; // ownership was handed off at wake time
        }
        let l = &mut self.rwlocks[rw];
        if write {
            if l.writer.is_none() && l.readers == 0 {
                l.writer = Some(pid);
                true
            } else {
                l.contended += 1;
                false
            }
        } else if l.writer.is_none() && !l.waiters.iter().any(|(_, w)| *w) {
            l.readers += 1;
            true
        } else {
            l.contended += 1;
            false
        }
    }

    pub fn rw_enqueue(&mut self, rw: ObjId, pid: Pid, write: bool) {
        self.rwlocks[rw].waiters.push_back((pid, write));
    }

    /// Release; when the lock becomes free, ownership is granted directly
    /// to the front of the queue: either the first parked writer, or the
    /// leading run of parked readers (all admitted together). Returns the
    /// pids to wake.
    pub fn rw_unlock(&mut self, rw: ObjId, pid: Pid, write: bool) -> Vec<Pid> {
        {
            let l = &mut self.rwlocks[rw];
            if write {
                debug_assert_eq!(l.writer, Some(pid));
                l.writer = None;
            } else {
                debug_assert!(l.readers > 0);
                l.readers -= 1;
                if l.readers > 0 {
                    return Vec::new();
                }
            }
        }
        self.rw_grant_next(rw)
    }

    /// Grant the freed lock to the queue front (writer, or reader run).
    fn rw_grant_next(&mut self, rw: ObjId) -> Vec<Pid> {
        let mut granted = Vec::new();
        {
            let l = &mut self.rwlocks[rw];
            match l.waiters.front() {
                None => return granted,
                Some(&(_, true)) => {
                    let (p, _) = l.waiters.pop_front().unwrap();
                    l.writer = Some(p);
                    granted.push(p);
                }
                Some(&(_, false)) => {
                    while let Some(&(p, w)) = l.waiters.front() {
                        if w {
                            break;
                        }
                        l.waiters.pop_front();
                        l.readers += 1;
                        granted.push(p);
                    }
                }
            }
        }
        for p in &granted {
            self.rw_granted.insert((rw, *p));
        }
        granted
    }

    // ---- flags / txn metrics ----------------------------------------------

    pub fn set_flag(&mut self, f: ObjId) {
        self.flags[f] = true;
    }

    pub fn flag(&self, f: ObjId) -> bool {
        self.flags[f]
    }

    pub fn txn_start(&mut self, pid: Pid, now: Time) {
        self.txn_start.insert(pid, now);
    }

    pub fn txn_end(&mut self, pid: Pid, now: Time) {
        if let Some(t0) = self.txn_start.remove(&pid) {
            self.latencies.push(now.saturating_sub(t0));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutex_handoff_fifo() {
        let mut w = World::new();
        let m = w.new_mutex();
        assert!(w.mutex_lock(m, 1));
        assert!(!w.mutex_lock(m, 2));
        assert!(!w.mutex_lock(m, 3));
        assert_eq!(w.mutex_unlock(m, 1), Some(2));
        assert_eq!(w.mutexes[m].holder, Some(2));
        assert_eq!(w.mutex_unlock(m, 2), Some(3));
        assert_eq!(w.mutex_unlock(m, 3), None);
        assert!(w.mutexes[m].holder.is_none());
        assert_eq!(w.mutexes[m].contended, 2);
    }

    #[test]
    fn barrier_releases_at_parties() {
        let mut w = World::new();
        let b = w.new_barrier(3);
        assert!(w.barrier_arrive(b, 1).is_none());
        assert!(w.barrier_arrive(b, 2).is_none());
        let woken = w.barrier_arrive(b, 3).unwrap();
        assert_eq!(woken, vec![1, 2]);
        // Reusable: next generation starts empty.
        assert!(w.barrier_arrive(b, 4).is_none());
        assert_eq!(w.barriers[b].generation, 1);
    }

    #[test]
    fn queue_bounded_push_pop() {
        let mut w = World::new();
        let q = w.new_queue(2);
        assert!(w.queue_try_push(q, 1).is_ok());
        assert!(w.queue_try_push(q, 1).is_ok());
        assert!(w.queue_try_push(q, 1).is_err()); // full; pid 1 queued
        assert_eq!(w.queues[q].push_waiters.len(), 1);
        // Pop frees a slot and hands the waiter back for waking.
        let woken = w.queue_try_pop(q, 2).unwrap();
        assert_eq!(woken, Some(1));
    }

    #[test]
    fn queue_pop_blocks_when_empty() {
        let mut w = World::new();
        let q = w.new_queue(4);
        assert!(w.queue_try_pop(q, 9).is_err());
        let woken = w.queue_try_push(q, 1).unwrap();
        assert_eq!(woken, Some(9));
    }

    #[test]
    fn latch_opens_once() {
        let mut w = World::new();
        let l = w.new_latch(2);
        assert!(!w.latch_wait(l, 5));
        assert!(w.latch_signal(l).is_empty());
        assert_eq!(w.latch_signal(l), vec![5]);
        assert!(w.latch_wait(l, 6)); // already open
    }

    #[test]
    fn channel_send_recv() {
        let mut w = World::new();
        let ch = w.new_channel();
        assert!(!w.chan_try_recv(ch, 1, true)); // blocked receiver queued
        assert_eq!(w.chan_send(ch), Some(1));
        assert!(w.chan_try_recv(ch, 1, true)); // message available now
    }

    #[test]
    fn channel_spin_mode_does_not_queue() {
        let mut w = World::new();
        let ch = w.new_channel();
        assert!(!w.chan_try_recv(ch, 1, false));
        assert_eq!(w.chan_send(ch), None); // no one to wake: spinner polls
    }

    #[test]
    fn rwlock_readers_share_writers_exclude() {
        let mut w = World::new();
        let rw = w.new_rwlock();
        assert!(w.rw_try(rw, 1, false));
        assert!(w.rw_try(rw, 2, false));
        assert!(!w.rw_try(rw, 3, true)); // writer blocked by readers
        assert!(w.rw_unlock(rw, 1, false).is_empty());
        w.rw_enqueue(rw, 3, true);
        let woken = w.rw_unlock(rw, 2, false);
        assert_eq!(woken, vec![3]);
        assert!(w.rw_try(rw, 3, true));
        assert!(!w.rw_try(rw, 4, false)); // reader blocked by writer
        let woken2 = w.rw_unlock(rw, 3, true);
        assert!(woken2.is_empty()); // pid 4 spun, never enqueued
    }

    #[test]
    fn rwlock_parked_writer_blocks_new_readers() {
        let mut w = World::new();
        let rw = w.new_rwlock();
        assert!(w.rw_try(rw, 1, false)); // reader in
        assert!(!w.rw_try(rw, 2, true)); // writer fails…
        w.rw_enqueue(rw, 2, true); // …and parks
        assert!(!w.rw_try(rw, 3, false)); // new reader cannot barge
        let woken = w.rw_unlock(rw, 1, false);
        assert_eq!(woken, vec![2]); // writer gets its turn
    }

    #[test]
    fn txn_latency_recorded() {
        let mut w = World::new();
        w.txn_start(1, 100);
        w.txn_end(1, 350);
        assert_eq!(w.latencies, vec![250]);
    }
}
