//! Synthetic binary images: symbol tables and `addr2line`-style mapping.
//!
//! Each synthetic application carries a symbol table assigning every
//! function an address range, source file and base line. The profiler's
//! symbolization step resolves sampled instruction pointers through this
//! table exactly as GAPP shells out to `addr2line` (paper §4.4), including
//! the PIE failure mode of §6.1: when the "binary" is position-independent
//! and no load bias is known, resolution fails and samples stay raw.

/// Index of a function symbol in its [`SymbolTable`].
pub type SymId = usize;

/// Bytes of address space given to each function.
pub const FUNC_SIZE: u64 = 4096;
/// Address-to-line granularity: one source line per 16 bytes of text.
pub const BYTES_PER_LINE: u64 = 16;
/// Base load address of non-PIE text segments (x86-64 convention).
pub const TEXT_BASE: u64 = 0x40_0000;

/// One function symbol.
#[derive(Clone, Debug)]
pub struct FuncSym {
    pub name: String,
    pub file: String,
    pub base_line: u32,
    pub addr: u64,
    pub size: u64,
}

/// A synthetic binary's symbol table.
#[derive(Clone, Debug, Default)]
pub struct SymbolTable {
    funcs: Vec<FuncSym>,
    /// Position-independent executable: addresses are unresolvable until
    /// the load bias is known (the gcc default the paper must override).
    pub pie: bool,
}

/// A resolved source location.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Location {
    pub function: String,
    pub file: String,
    pub line: u32,
}

impl SymbolTable {
    pub fn new() -> SymbolTable {
        SymbolTable::default()
    }

    /// Register a function; returns its symbol id.
    pub fn add(&mut self, name: &str, file: &str, base_line: u32) -> SymId {
        let addr = TEXT_BASE + (self.funcs.len() as u64) * FUNC_SIZE;
        self.funcs.push(FuncSym {
            name: name.to_string(),
            file: file.to_string(),
            base_line,
            addr,
            size: FUNC_SIZE,
        });
        self.funcs.len() - 1
    }

    pub fn len(&self) -> usize {
        self.funcs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.funcs.is_empty()
    }

    /// Base address of a function (what `Call` pushes on the stack).
    pub fn addr_of(&self, id: SymId) -> u64 {
        self.funcs[id].addr
    }

    /// Instruction pointer for byte offset `off` within function `id`.
    pub fn ip(&self, id: SymId, off: u64) -> u64 {
        let f = &self.funcs[id];
        f.addr + off.min(f.size - 1)
    }

    pub fn func(&self, id: SymId) -> &FuncSym {
        &self.funcs[id]
    }

    /// Find the symbol containing `addr`.
    pub fn find(&self, addr: u64) -> Option<(SymId, &FuncSym)> {
        if self.funcs.is_empty() || addr < TEXT_BASE {
            return None;
        }
        let idx = ((addr - TEXT_BASE) / FUNC_SIZE) as usize;
        let f = self.funcs.get(idx)?;
        if addr < f.addr + f.size {
            Some((idx, f))
        } else {
            None
        }
    }

    /// `addr2line`: resolve an address to function/file/line. Fails for
    /// PIE binaries (paper §6.1) and for addresses outside the image
    /// (shared-library / kernel samples, paper §4.4).
    pub fn addr2line(&self, addr: u64) -> Option<Location> {
        if self.pie {
            return None;
        }
        let (_, f) = self.find(addr)?;
        let line = f.base_line + ((addr - f.addr) / BYTES_PER_LINE) as u32;
        Some(Location {
            function: f.name.clone(),
            file: f.file.clone(),
            line,
        })
    }

    /// Function name only (bcc's `sym()` can do this even for PIE, which
    /// is the paper's suggested workaround).
    pub fn sym_name(&self, addr: u64) -> Option<&str> {
        self.find(addr).map(|(_, f)| f.name.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addresses_disjoint_and_ordered() {
        let mut st = SymbolTable::new();
        let a = st.add("f", "a.c", 10);
        let b = st.add("g", "a.c", 50);
        assert_eq!(st.addr_of(b), st.addr_of(a) + FUNC_SIZE);
    }

    #[test]
    fn addr2line_maps_offsets_to_lines() {
        let mut st = SymbolTable::new();
        let f = st.add("CNDF", "blackscholes.c", 100);
        let loc = st.addr2line(st.ip(f, 0)).unwrap();
        assert_eq!(loc.function, "CNDF");
        assert_eq!(loc.line, 100);
        let loc2 = st.addr2line(st.ip(f, 5 * BYTES_PER_LINE)).unwrap();
        assert_eq!(loc2.line, 105);
        assert_eq!(loc2.file, "blackscholes.c");
    }

    #[test]
    fn pie_defeats_addr2line_but_not_sym() {
        let mut st = SymbolTable::new();
        let f = st.add("emd", "ferret.c", 1);
        st.pie = true;
        assert!(st.addr2line(st.ip(f, 0)).is_none());
        assert_eq!(st.sym_name(st.ip(f, 0)), Some("emd"));
    }

    #[test]
    fn out_of_image_addresses_unresolved() {
        let mut st = SymbolTable::new();
        st.add("f", "a.c", 1);
        assert!(st.addr2line(0x10).is_none()); // below text base
        assert!(st.addr2line(TEXT_BASE + 100 * FUNC_SIZE).is_none()); // beyond
    }

    #[test]
    fn ip_clamped_to_function() {
        let mut st = SymbolTable::new();
        let f = st.add("f", "a.c", 1);
        assert_eq!(st.ip(f, 1 << 30), st.addr_of(f) + FUNC_SIZE - 1);
    }
}
