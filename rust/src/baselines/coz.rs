//! Coz-style causal profiling [Curtsinger & Berger, SOSP'15].
//!
//! Coz estimates "what if line L were S% faster?" by *virtually speeding
//! up* L: whenever a sampled thread executes L, every other thread is
//! delayed proportionally. Experiments are chosen randomly at run time;
//! the paper's §6 complaint is that this makes results hard to reproduce
//! across runs on smaller machines. This implementation runs real
//! randomized experiments over the simulated execution's sample stream
//! and exhibits exactly that run-to-run variance (measured in B2).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::simkernel::{Event, Kernel, KernelConfig, Probe, Time};
use crate::util::Prng;
use crate::workload::App;

/// One virtual-speedup experiment outcome.
#[derive(Clone, Debug)]
pub struct Experiment {
    pub addr: u64,
    pub speedup_pct: u32,
    /// Estimated program-level impact (fraction of runtime).
    pub impact: f64,
}

/// Aggregated result: per-line estimated impact.
#[derive(Clone, Debug, Default)]
pub struct CozResult {
    pub lines: Vec<(u64, f64)>,
    pub experiments: Vec<Experiment>,
}

impl CozResult {
    /// Ranked line addresses, best first.
    pub fn ranking(&self) -> Vec<u64> {
        self.lines.iter().map(|(a, _)| *a).collect()
    }
}

struct CozState {
    rng: Prng,
    /// Current experiment target (sampled address) and window end.
    current: Option<(u64, u32, Time)>,
    /// Samples of the target within the current window.
    window_hits: f64,
    /// All samples within the current window (normalizer).
    window_total: u64,
    /// addr → total samples (for normalization).
    totals: HashMap<u64, u64>,
    experiments: Vec<Experiment>,
    window_ns: Time,
}

/// The sampling probe: periodic IP samples drive experiment selection.
pub struct CozProbeHandle {
    state: Rc<RefCell<CozState>>,
    dt: Time,
}

impl Probe for CozProbeHandle {
    fn on_event(&mut self, ev: &Event<'_>) -> u64 {
        let Event::SampleTick { time, view } = ev else {
            return 100;
        };
        let mut s = self.state.borrow_mut();
        *s.totals.entry(view.ip).or_insert(0) += 1;
        match s.current {
            Some((addr, speedup, until)) if *time < until => {
                // Within the experiment window: samples of the target
                // line contribute impact ∝ virtual speedup.
                s.window_total += 1;
                if view.ip == addr {
                    s.window_hits += speedup as f64 / 100.0;
                }
                300
            }
            _ => {
                // Close the previous experiment: impact is the target's
                // weighted share of the window's samples (Coz's
                // program-speedup estimate from one experiment).
                if let Some((addr, speedup, _)) = s.current.take() {
                    let impact = if s.window_total > 0 {
                        s.window_hits / s.window_total as f64
                    } else {
                        0.0
                    };
                    s.window_hits = 0.0;
                    s.window_total = 0;
                    s.experiments.push(Experiment {
                        addr,
                        speedup_pct: speedup,
                        impact,
                    });
                }
                // Randomly choose the next experiment: an address drawn
                // with probability ∝ its sample count (Coz experiments
                // on lines it observes executing) and a random virtual
                // speedup.
                let total: u64 = s.totals.values().sum();
                if total > 0 {
                    let mut draw = s.rng.below(total);
                    let mut chosen = 0u64;
                    // Sorted iteration: the draw→address mapping must be
                    // deterministic per seed (HashMap order is not).
                    let mut entries: Vec<(u64, u64)> =
                        s.totals.iter().map(|(a, c)| (*a, *c)).collect();
                    entries.sort_unstable();
                    for (addr, cnt) in entries {
                        if draw < cnt {
                            chosen = addr;
                            break;
                        }
                        draw -= cnt;
                    }
                    let speedup = 5 + 5 * s.rng.below(20) as u32; // 5..100%
                    let until = *time + s.window_ns;
                    s.current = Some((chosen, speedup, until));
                }
                500
            }
        }
    }

    fn sample_period(&self) -> Option<Time> {
        Some(self.dt)
    }
}

/// Driver: run an app under the Coz-like profiler.
pub struct CozProfiler {
    state: Rc<RefCell<CozState>>,
    dt: Time,
}

impl CozProfiler {
    pub fn new(seed: u64) -> CozProfiler {
        CozProfiler {
            state: Rc::new(RefCell::new(CozState {
                rng: Prng::new(seed),
                current: None,
                window_hits: 0.0,
                window_total: 0,
                totals: HashMap::new(),
                experiments: Vec::new(),
                window_ns: 2_000_000, // 2 ms experiment windows
            })),
            dt: 200_000, // 200 µs sampling
        }
    }

    pub fn probe(&self) -> Box<dyn Probe> {
        Box::new(CozProbeHandle {
            state: self.state.clone(),
            dt: self.dt,
        })
    }

    /// Run an app to completion and aggregate per-line impact.
    pub fn run(app: &App, kcfg: KernelConfig, seed: u64) -> anyhow::Result<CozResult> {
        let prof = CozProfiler::new(seed);
        let mut k = Kernel::new(kcfg);
        k.attach_probe(prof.probe());
        app.spawn_into(&mut k);
        k.run()?;
        let s = prof.state.borrow();
        let mut per_line: HashMap<u64, f64> = HashMap::new();
        for e in &s.experiments {
            if e.impact > 0.0 {
                *per_line.entry(e.addr).or_insert(0.0) += e.impact;
            }
        }
        let mut lines: Vec<(u64, f64)> = per_line.into_iter().collect();
        lines.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        Ok(CozResult {
            lines,
            experiments: s.experiments.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::apps;

    #[test]
    fn coz_produces_rankings() {
        let app = apps::ferret(
            3,
            apps::FerretConfig {
                queries: 80,
                ..apps::FerretConfig::with_alloc(4, 2, 6, 10)
            },
        );
        let r = CozProfiler::run(&app, KernelConfig::default(), 1).unwrap();
        assert!(!r.experiments.is_empty());
        assert!(!r.lines.is_empty());
    }

    #[test]
    fn coz_rankings_vary_across_seeds() {
        // The §6 reproducibility complaint: different seeds → different
        // top lines, unlike GAPP (deterministic per input).
        let top_for = |seed| {
            let app = apps::ferret(
                3,
                apps::FerretConfig {
                    queries: 80,
                    ..apps::FerretConfig::with_alloc(4, 2, 6, 10)
                },
            );
            CozProfiler::run(&app, KernelConfig::default(), seed)
                .unwrap()
                .ranking()
                .into_iter()
                .take(3)
                .collect::<Vec<_>>()
        };
        let a = top_for(1);
        let mut differs = false;
        for seed in 2..6 {
            if top_for(seed) != a {
                differs = true;
                break;
            }
        }
        assert!(differs, "coz rankings unexpectedly stable");
    }
}
