//! Comparator profilers from the paper's §6 related-work discussion.
//!
//! Each is a real (if compact) implementation of the cited system's core
//! mechanism, attached to the same simulated kernel through the same
//! [`crate::simkernel::Probe`] interface, so the comparisons in
//! `experiments/baselines_cmp.rs` measure mechanism against mechanism:
//!
//! * [`wperf`] — wPerf-style off-CPU analysis [31]: record waiting
//!   segments, build the wait-for graph, detect knots. Much heavier
//!   post-processing than GAPP (the paper quotes 271.9 s vs 3 s).
//! * [`coz`] — Coz-style causal profiling [10]: randomized virtual-
//!   speedup experiments; results vary across runs (the paper's
//!   reproducibility complaint).
//! * [`crit_stacks`] — Criticality-Stacks-style ranking [14] that counts
//!   a thread active only while it *occupies a core*; goes wrong when
//!   threads > CPUs (the paper's §6 argument for using TASK_RUNNING).

pub mod wperf;
pub mod coz;
pub mod crit_stacks;

pub use coz::{CozProfiler, CozResult};
pub use crit_stacks::{CritStacksProbeHandle, CritStacksProfiler};
pub use wperf::{WPerfProbeHandle, WPerfProfiler, WaitForGraph};
