//! Criticality-Stacks-style thread ranking [Du Bois et al., ISCA'13]
//! with the *on-CPU* definition of "active".
//!
//! The original proposal counts a thread as active only while it
//! occupies a core. GAPP's §6 argues this miscounts the degree of
//! parallelism whenever there are more runnable threads than CPUs (or
//! other applications share the machine): runnable-but-queued threads
//! are parallelism that the on-CPU definition misses. This probe
//! implements the on-CPU variant of the same CMetric so experiment B3
//! can show the divergence directly.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::simkernel::{Event, Kernel, KernelConfig, Pid, Probe, TaskState, Time};
use crate::workload::App;

struct State {
    /// pid → on-CPU since (for currently-running threads).
    running: HashMap<Pid, Time>,
    /// Number of app threads currently on a CPU.
    on_cpu: usize,
    t_switch: Time,
    global_cm: f64,
    local_cm: HashMap<Pid, f64>,
    pub cm: HashMap<Pid, f64>,
    /// Total busy wall time (≥1 app thread on a CPU).
    pub busy_ns: f64,
    app_threads: std::collections::HashSet<Pid>,
}

impl State {
    fn advance(&mut self, now: Time) {
        let dur = now.saturating_sub(self.t_switch);
        self.t_switch = now;
        if dur > 0 && self.on_cpu > 0 {
            self.global_cm += dur as f64 / self.on_cpu as f64;
            self.busy_ns += dur as f64;
        }
    }
}

pub struct CritStacksProbeHandle {
    state: Rc<RefCell<State>>,
}

impl Probe for CritStacksProbeHandle {
    fn on_event(&mut self, ev: &Event<'_>) -> u64 {
        let mut s = self.state.borrow_mut();
        match ev {
            Event::TaskNew { pid, .. } => {
                s.app_threads.insert(*pid);
                300
            }
            Event::SchedSwitch {
                time,
                prev_pid,
                next_pid,
                ..
            } => {
                s.advance(*time);
                // prev leaves a core: close its on-CPU slice.
                if s.app_threads.contains(prev_pid) {
                    if s.running.remove(prev_pid).is_some() {
                        s.on_cpu = s.on_cpu.saturating_sub(1);
                        let local = s.local_cm.remove(prev_pid).unwrap_or(0.0);
                        let delta = (s.global_cm - local).max(0.0);
                        *s.cm.entry(*prev_pid).or_insert(0.0) += delta;
                    }
                }
                // next takes a core.
                if s.app_threads.contains(next_pid) {
                    s.running.insert(*next_pid, *time);
                    s.on_cpu += 1;
                    let g = s.global_cm;
                    s.local_cm.insert(*next_pid, g);
                }
                let _ = TaskState::Running;
                800
            }
            _ => 100,
        }
    }
}

/// Driver producing per-thread CMetric under the on-CPU definition.
pub struct CritStacksProfiler {
    state: Rc<RefCell<State>>,
}

impl Default for CritStacksProfiler {
    fn default() -> Self {
        CritStacksProfiler {
            state: Rc::new(RefCell::new(State {
                running: HashMap::new(),
                on_cpu: 0,
                t_switch: 0,
                global_cm: 0.0,
                local_cm: HashMap::new(),
                cm: HashMap::new(),
                busy_ns: 0.0,
                app_threads: std::collections::HashSet::new(),
            })),
        }
    }
}

impl CritStacksProfiler {
    pub fn probe(&self) -> Box<dyn Probe> {
        Box::new(CritStacksProbeHandle {
            state: self.state.clone(),
        })
    }

    /// Run and return per-thread on-CPU CMetric (ns) plus the implied
    /// average parallelism estimate `busy / global_cm`.
    pub fn run(app: &App, kcfg: KernelConfig) -> anyhow::Result<(HashMap<Pid, f64>, f64)> {
        let prof = CritStacksProfiler::default();
        let mut k = Kernel::new(kcfg);
        k.attach_probe(prof.probe());
        app.spawn_into(&mut k);
        k.run()?;
        let state = prof.state.borrow();
        let avg_par = if state.global_cm > 0.0 {
            state.busy_ns / state.global_cm
        } else {
            0.0
        };
        Ok((state.cm.clone(), avg_par))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::apps;

    #[test]
    fn on_cpu_cm_accumulates() {
        let app = apps::blackscholes(8, 3);
        let (cm, avg) = CritStacksProfiler::run(&app, KernelConfig::default()).unwrap();
        assert!(!cm.is_empty());
        assert!(cm.values().all(|v| *v >= 0.0));
        assert!(cm.values().sum::<f64>() > 0.0);
        assert!(avg >= 1.0);
    }

    #[test]
    fn oversubscription_distorts_on_cpu_parallelism() {
        // 32 workers on 8 CPUs: runnable-but-queued threads are invisible
        // to the on-CPU definition, so its average-parallelism estimate
        // saturates at 8 while GAPP's TASK_RUNNING count reaches ~33 —
        // the §6 failure mode. (Totals are conserved by construction, so
        // the observable divergence is the parallelism estimate, which
        // drives the criticality trigger.)
        let kcfg = KernelConfig {
            cpus: 8,
            ..Default::default()
        };
        let app = apps::blackscholes(32, 3);
        let (_, oncpu_avg) = CritStacksProfiler::run(&app, kcfg.clone()).unwrap();
        assert!(oncpu_avg <= 8.0 + 1e-6, "oncpu_avg={oncpu_avg:.2}");

        let app2 = apps::blackscholes(32, 3);
        let (report, _) = crate::gapp::profile(
            &app2,
            kcfg,
            crate::gapp::GappConfig::default(),
            crate::runtime::AnalysisEngine::native(),
        )
        .unwrap();
        // GAPP's per-thread average parallelism (wall/cm) in the same
        // run: the busy workers see ~33 runnable threads.
        let gapp_avg = {
            let (w, c): (f64, f64) = report
                .threads
                .iter()
                .fold((0.0, 0.0), |(w, c), t| (w + t.wall_ms, c + t.cm_ms));
            w / c.max(1e-9)
        };
        assert!(
            gapp_avg > 2.0 * oncpu_avg,
            "gapp_avg={gapp_avg:.2} oncpu_avg={oncpu_avg:.2}"
        );
    }
}
