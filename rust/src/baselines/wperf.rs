//! wPerf-style off-CPU analysis [Zhou et al., OSDI'18].
//!
//! Records every waiting segment (who blocked, who woke it, for how
//! long, with what stack) by tracing the same switch/wakeup events GAPP
//! uses, then post-processes: build the wait-for graph, compute its
//! strongly-connected components, and rank "knots" by accumulated wait.
//! The post-processing walks the full per-segment trace several times —
//! that is the structural reason its PPT is orders of magnitude above
//! GAPP's (§6: 271.9 s vs 3 s for MySQL), which the baseline-comparison
//! experiment reproduces in shape.

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::rc::Rc;

use crate::simkernel::{Event, Pid, Probe, TaskState, Time};

/// One recorded waiting segment.
#[derive(Clone, Debug)]
pub struct WaitSegment {
    pub waiter: Pid,
    pub waker: Pid,
    pub blocked_at: Time,
    pub woken_at: Time,
    pub stack: Vec<u64>,
}

/// The wait-for graph: edge (a → b) = total time a spent waiting to be
/// woken by b.
#[derive(Clone, Debug, Default)]
pub struct WaitForGraph {
    pub edges: HashMap<(Pid, Pid), Time>,
    pub nodes: Vec<Pid>,
}

impl WaitForGraph {
    /// Strongly connected components (iterative Tarjan).
    pub fn sccs(&self) -> Vec<Vec<Pid>> {
        let mut index: HashMap<Pid, usize> = HashMap::new();
        let mut low: HashMap<Pid, usize> = HashMap::new();
        let mut on_stack: HashMap<Pid, bool> = HashMap::new();
        let mut stack: Vec<Pid> = Vec::new();
        let mut next = 0usize;
        let mut out = Vec::new();
        let adj: HashMap<Pid, Vec<Pid>> = {
            let mut m: HashMap<Pid, Vec<Pid>> = HashMap::new();
            for (a, b) in self.edges.keys() {
                m.entry(*a).or_default().push(*b);
            }
            m
        };
        // Iterative DFS with an explicit frame stack.
        for &start in &self.nodes {
            if index.contains_key(&start) {
                continue;
            }
            let mut frames: Vec<(Pid, usize)> = vec![(start, 0)];
            while let Some(&mut (v, ref mut ei)) = frames.last_mut() {
                if *ei == 0 {
                    index.insert(v, next);
                    low.insert(v, next);
                    next += 1;
                    stack.push(v);
                    on_stack.insert(v, true);
                }
                let succs = adj.get(&v).cloned().unwrap_or_default();
                if *ei < succs.len() {
                    let w = succs[*ei];
                    *ei += 1;
                    if !index.contains_key(&w) {
                        frames.push((w, 0));
                    } else if on_stack.get(&w).copied().unwrap_or(false) {
                        let lv = (*low.get(&v).unwrap()).min(*index.get(&w).unwrap());
                        low.insert(v, lv);
                    }
                } else {
                    frames.pop();
                    if let Some(&mut (p, _)) = frames.last_mut() {
                        let lv = (*low.get(&p).unwrap()).min(*low.get(&v).unwrap());
                        low.insert(p, lv);
                    }
                    if low[&v] == index[&v] {
                        let mut comp = Vec::new();
                        while let Some(w) = stack.pop() {
                            on_stack.insert(w, false);
                            comp.push(w);
                            if w == v {
                                break;
                            }
                        }
                        out.push(comp);
                    }
                }
            }
        }
        out
    }
}

/// Probe state shared with the kernel.
pub struct WPerfState {
    /// pid → (blocked_at, stack) for currently-blocked threads.
    blocked: HashMap<Pid, (Time, Vec<u64>)>,
    /// pid of the task running on each cpu (to attribute wakers).
    running: Vec<Pid>,
    pub segments: Vec<WaitSegment>,
    pub events: u64,
}

/// Final analysis output.
#[derive(Clone, Debug)]
pub struct WPerfReport {
    pub graph: WaitForGraph,
    /// (component, total internal wait) — "knots", heaviest first.
    pub knots: Vec<(Vec<Pid>, Time)>,
    pub segments: usize,
    pub ppt_seconds: f64,
}

pub struct WPerfProfiler {
    pub state: Rc<RefCell<WPerfState>>,
}

pub struct WPerfProbeHandle {
    state: Rc<RefCell<WPerfState>>,
}

impl Probe for WPerfProbeHandle {
    fn on_event(&mut self, ev: &Event<'_>) -> u64 {
        let mut s = self.state.borrow_mut();
        s.events += 1;
        match ev {
            Event::SchedSwitch {
                time,
                cpu,
                prev_pid,
                prev_state,
                next_pid,
                prev_stack,
                ..
            } => {
                if *prev_state == TaskState::Blocked && *prev_pid != 0 {
                    // Events borrow the stack; wPerf keeps per-segment
                    // copies (the memory cost §6 attributes to it).
                    s.blocked
                        .insert(*prev_pid, (*time, prev_stack.to_vec()));
                }
                if *cpu < s.running.len() {
                    s.running[*cpu] = *next_pid;
                }
                // wPerf hooks the same tracepoints; charge a similar cost.
                600
            }
            Event::SchedWakeup { time, cpu, pid } => {
                let waker = if *cpu < s.running.len() {
                    s.running[*cpu]
                } else {
                    0
                };
                if let Some((t0, stack)) = s.blocked.remove(pid) {
                    let seg = WaitSegment {
                        waiter: *pid,
                        waker,
                        blocked_at: t0,
                        woken_at: *time,
                        stack,
                    };
                    s.segments.push(seg);
                }
                400
            }
            _ => 200,
        }
    }
}

impl Default for WPerfProfiler {
    fn default() -> Self {
        Self::new(64)
    }
}

impl WPerfProfiler {
    pub fn new(ncpu: usize) -> WPerfProfiler {
        WPerfProfiler {
            state: Rc::new(RefCell::new(WPerfState {
                blocked: HashMap::new(),
                running: vec![0; ncpu],
                segments: Vec::new(),
                events: 0,
            })),
        }
    }

    pub fn probe(&self) -> Box<dyn Probe> {
        Box::new(WPerfProbeHandle {
            state: self.state.clone(),
        })
    }

    /// Post-processing: build the graph, find knots, rank them. This is
    /// deliberately the full multi-pass pipeline wPerf describes — its
    /// cost scales with the *segment trace*, not the report size.
    pub fn finish(&self) -> WPerfReport {
        let t0 = std::time::Instant::now();
        let s = self.state.borrow();
        let mut graph = WaitForGraph::default();
        // Pass 1: nodes.
        let mut seen: Vec<Pid> = Vec::new();
        for seg in &s.segments {
            if !seen.contains(&seg.waiter) {
                seen.push(seg.waiter);
            }
            if !seen.contains(&seg.waker) {
                seen.push(seg.waker);
            }
        }
        graph.nodes = seen;
        // Pass 2: edges.
        for seg in &s.segments {
            *graph
                .edges
                .entry((seg.waiter, seg.waker))
                .or_insert(0) += seg.woken_at - seg.blocked_at;
        }
        // Pass 3: per-segment cascaded-wait expansion (the quadratic-ish
        // refinement pass that dominates wPerf's PPT): for every segment,
        // walk the queue of transitively-implied waits.
        let mut cascade: HashMap<Pid, Time> = HashMap::new();
        for seg in &s.segments {
            let mut frontier: VecDeque<(Pid, Time)> = VecDeque::new();
            frontier.push_back((seg.waker, seg.woken_at - seg.blocked_at));
            let mut hops = 0;
            while let Some((p, w)) = frontier.pop_front() {
                *cascade.entry(p).or_insert(0) += w;
                hops += 1;
                if hops > 8 {
                    break;
                }
                // Who was this waker itself waiting on during the window?
                for other in &s.segments {
                    if other.waiter == p
                        && other.blocked_at < seg.woken_at
                        && other.woken_at > seg.blocked_at
                    {
                        frontier.push_back((other.waker, w / 2));
                        break;
                    }
                }
            }
        }
        // Pass 4: knots = SCCs ranked by internal wait.
        let sccs = graph.sccs();
        let mut knots: Vec<(Vec<Pid>, Time)> = sccs
            .into_iter()
            .map(|comp| {
                let total: Time = graph
                    .edges
                    .iter()
                    .filter(|((a, b), _)| comp.contains(a) && comp.contains(b))
                    .map(|(_, w)| *w)
                    .sum::<Time>()
                    + comp
                        .iter()
                        .map(|p| cascade.get(p).copied().unwrap_or(0) / 16)
                        .sum::<Time>();
                (comp, total)
            })
            .collect();
        knots.sort_by(|a, b| b.1.cmp(&a.1));
        WPerfReport {
            graph,
            knots,
            segments: s.segments.len(),
            ppt_seconds: t0.elapsed().as_secs_f64(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gapp::run_unprofiled;
    use crate::simkernel::{Kernel, KernelConfig};
    use crate::workload::apps;

    #[test]
    fn records_wait_segments_for_pipeline_app() {
        let app = apps::dedup(3, apps::DedupConfig {
            chunks: 60,
            ..apps::DedupConfig::with_alloc(4, 4, 4)
        });
        let prof = WPerfProfiler::new(64);
        let mut k = Kernel::new(KernelConfig::default());
        k.attach_probe(prof.probe());
        app.spawn_into(&mut k);
        k.run().unwrap();
        let report = prof.finish();
        assert!(report.segments > 10, "segments={}", report.segments);
        assert!(!report.graph.edges.is_empty());
        assert!(!report.knots.is_empty());
    }

    #[test]
    fn wperf_overhead_comparable_to_gapp() {
        let app = apps::canneal(8, 5);
        let (base, _) = run_unprofiled(&app, KernelConfig::default()).unwrap();
        let app2 = apps::canneal(8, 5);
        let prof = WPerfProfiler::new(64);
        let mut k = Kernel::new(KernelConfig::default());
        k.attach_probe(prof.probe());
        app2.spawn_into(&mut k);
        let end = k.run().unwrap();
        let oh = (end.saturating_sub(base)) as f64 / base as f64;
        assert!(oh < 0.25, "oh={oh:.3}"); // §6: "broadly similar to GAPP"
    }

    #[test]
    fn scc_detects_cycles() {
        let mut g = WaitForGraph::default();
        g.nodes = vec![1, 2, 3, 4];
        g.edges.insert((1, 2), 10);
        g.edges.insert((2, 1), 5); // knot {1,2}
        g.edges.insert((3, 4), 7); // chain
        let sccs = g.sccs();
        let knot = sccs.iter().find(|c| c.len() == 2).expect("2-cycle");
        let mut k = knot.clone();
        k.sort();
        assert_eq!(k, vec![1, 2]);
    }
}
