//! GAPP command-line interface: profile synthetic applications and
//! regenerate every table/figure from the paper.
//!
//! ```text
//! gapp list-apps
//! gapp profile --app dedup [--threads 64] [--seed 7] [--nmin 8] [--dt-us 3000]
//!              [--shards N] [--ring-capacity R] [--merge serial|tree]
//!              [--lane-threads N] [--format text|json|jsonl] [--output FILE]
//! gapp live --app mysql --app dedup --window-us 5000 [--top 5] [--lru]
//!           [--shards N] [--ring-capacity R] [--merge serial|tree]
//!           [--lane-threads N] [--shard-partials] [--on-overflow shed|degrade]
//!           [--checkpoint FILE] [--checkpoint-every N] [--resume FILE]
//!           [--fault-plan FILE] [--stream PATH]
//!           [--compact-base B] [--decay-half-life-us H]
//!           [--format text|json|jsonl] [--output FILE]
//!                                  # streaming analyzer: epoch-windowed
//!                                  # per-window top-K; repeat --app for
//!                                  # system-wide multi-app profiling
//! gapp aggregate FILE [FILE...]    # merge shard_window partials from
//!                                  # JSONL streams (one producer per
//!                                  # file); malformed lines are
//!                                  # quarantined and counted, never
//!                                  # trusted; `symbols` events, when
//!                                  # present, symbolize the report
//! gapp serve --listen PATH [--producers N] [--top K] [--horizon W]
//!            [--format text|json|jsonl] [--output FILE]
//!                                  # fleet aggregation service: accept
//!                                  # N `gapp live --stream PATH`
//!                                  # producers on a Unix socket and
//!                                  # re-emit ONE merged session (see
//!                                  # rust/src/fleet/); `aggregate` is
//!                                  # the one-shot special case
//! Transport is sharded per CPU (PERF_EVENT_ARRAY-style): one ring of
//! --ring-capacity records per shard, records routed to the CPU they
//! fired on and globally re-ordered by timestamp at read time.
//! --shards defaults to the CPU count; --shards 1 is the single shared
//! ring (provably equivalent output — only buffering behaviour differs).
//! --merge picks the shard-aggregation strategy: tree (default) folds
//! each shard locally and merges the partials pairwise; serial re-
//! serializes the shards into one globally-ordered stream. The two are
//! byte-identical (CI diffs them); --shard-partials additionally emits
//! one per-shard partial event per window (JSONL transport seam).
//! --lane-threads N (default 1) folds the tree strategy's shard lanes
//! on N real OS threads: drained records hand off to scoped lane
//! workers over SPSC channels and the window-close merge tree runs its
//! sibling merges concurrently. Output stays byte-identical at every N
//! (CI diffs 1 vs 4); N > 1 requires --merge tree and --shards > 1.
//! Output goes through a report sink: --format text (default; byte-
//! identical to the pre-sink CLI), json (one schema-versioned document
//! per session) or jsonl (one event per line — windows stream as they
//! close); --output writes to a file instead of stdout.
//! Durability: --checkpoint writes an atomic snapshot of the session
//! state every --checkpoint-every windows (default 1); --resume picks a
//! crashed session back up from that snapshot and finishes with output
//! byte-identical to an uninterrupted run. --on-overflow picks the
//! ring-overflow policy: shed (default; drop + count) or degrade
//! (emergency-drain near-full rings and widen the window once).
//! --fault-plan injects deterministic faults (overflow bursts, a
//! stalled shard, kill points) from a JSON plan — the crash-recovery
//! test harness, available in production builds on purpose.
//! --stream PATH (live only) attaches an extra flush-per-event JSONL
//! sink writing to a file, FIFO or Unix socket — the producer side of
//! `gapp serve`. It implies --shard-partials so the stream carries the
//! per-shard partials plus the `symbols` id → frames announcements the
//! fleet service re-interns by.
//! Bounded memory: --compact-base B (B >= 2; default off) folds closed
//! windows into a tier pyramid so a session retains O(B * log T) state
//! instead of O(T) — the cumulative report stays byte-identical to the
//! uncompacted run. --decay-half-life-us H adds a time-decayed "recent"
//! top-K (counts halve every H simulated µs) beside the cumulative one.
//! `gapp serve` takes --compact-base too, bounding the fleet fold.
//! gapp scenario run FILE [--seed N] [--format text|json|jsonl]
//!                        [--output FILE]
//!                                  # execute a scenarios/*.json spec:
//!                                  # injected pathologies with ground-
//!                                  # truth labels, report + scorecard
//! gapp scenario matrix FILE [...]  # sweep the spec's seeds × threads
//!                                  # matrix; per-case scorecards plus
//!                                  # a micro-averaged aggregate
//! gapp run --app ferret            # unprofiled baseline run
//! gapp table2 [--threads 64]       # Table 2
//! gapp fig3 | fig4 | fig5 | fig6 | fig7
//! gapp dedup-alloc                 # §5.2 Dedup allocations
//! gapp sweep                       # §5.1 Nmin / Δt sensitivity
//! gapp overhead                    # §5.4 overhead study
//! gapp baselines                   # §6 wPerf / Coz / CritStacks
//! gapp all                         # everything above, in order
//! Backend: --xla forces the AOT artifacts, --native the Rust fallback;
//! default auto-detects artifacts/.
//! ```

use anyhow::Context as _;

use gapp::experiments::{
    baselines_cmp, dedup_alloc, fig3, fig4, fig5, fig6, fig7, overhead, scenario_matrix,
    sensitivity, table2, EngineKind,
};
use gapp::fleet::{FleetMerge, ServeConfig, StreamSink};
use gapp::gapp::faults::FaultPlan;
use gapp::gapp::sink::{self, ReportSink};
use gapp::gapp::stream::LiveConfig;
use gapp::gapp::{
    run_unprofiled, GappConfig, MergeStrategy, OverflowPolicy, ReportFormat, Session,
};
use gapp::scenario::{self, Scenario};
use gapp::simkernel::KernelConfig;
use gapp::util::cli::Args;
use gapp::workload::apps;
use gapp::workload::App;

fn main() {
    let args = Args::from_env();
    let engine = EngineKind::from_flag(args.flag("xla"), args.flag("native"));
    let threads: usize = args.opt("threads", 64);
    let seed: u64 = args.opt("seed", 7);

    let result = match args.subcommand() {
        Some("list-apps") => {
            for a in apps::ALL_APPS {
                println!("{a}");
            }
            println!();
            println!("profile one:      gapp profile --app <name>");
            println!("profile several:  gapp live --app <name> --app <name> --window-us 5000");
            Ok(())
        }
        Some("run") => cmd_run(&args, threads, seed),
        Some("profile") => cmd_profile(&args, engine, threads, seed),
        Some("live") => cmd_live(&args, engine, threads, seed),
        Some("aggregate") => cmd_aggregate(&args),
        Some("serve") => cmd_serve(&args),
        Some("scenario") => cmd_scenario(&args, engine),
        Some("table2") => table2::run(engine, threads, seed)
            .map(|rows| println!("{}", table2::render(&rows))),
        Some("fig3") => fig3::run(engine, threads.min(32), seed)
            .map(|r| println!("{}", fig3::render(&r))),
        Some("fig4") => fig4::run(engine, seed).map(|r| println!("{}", fig4::render(&r))),
        Some("fig5") => fig5::run(engine, seed).map(|r| println!("{}", fig5::render(&r))),
        Some("fig6") => fig6::run(engine, seed).map(|r| println!("{}", fig6::render(&r))),
        Some("fig7") => fig7::run(engine, seed).map(|r| println!("{}", fig7::render(&r))),
        Some("dedup-alloc") => {
            dedup_alloc::run(engine, seed).map(|r| println!("{}", dedup_alloc::render(&r)))
        }
        Some("sweep") => {
            sensitivity::run(engine, seed).map(|r| println!("{}", sensitivity::render(&r)))
        }
        Some("overhead") => overhead::run(engine, threads, seed)
            .map(|r| println!("{}", overhead::render(&r))),
        Some("baselines") => baselines_cmp::run(engine, seed)
            .map(|r| println!("{}", baselines_cmp::render(&r))),
        Some("all") => cmd_all(engine, threads, seed),
        _ => {
            eprintln!("usage: see `gapp --help` header in rust/src/main.rs");
            eprintln!(
                "subcommands: list-apps run profile live aggregate serve scenario \
                 table2 fig3 fig4 fig5 fig6 fig7 dedup-alloc sweep overhead \
                 baselines all"
            );
            eprintln!(
                "live mode: gapp live --app mysql --app dedup --window-us 5000 \
                 [--top 5] [--lru] [--shards N] [--ring-capacity R] \
                 [--merge serial|tree] [--lane-threads N] [--shard-partials] \
                 [--on-overflow shed|degrade] [--compact-base B] \
                 [--decay-half-life-us H]"
            );
            eprintln!(
                "durability: profile/live take --checkpoint FILE \
                 [--checkpoint-every N] to snapshot, --resume FILE to pick a \
                 crashed session back up, --fault-plan FILE to inject faults;"
            );
            eprintln!(
                "            gapp aggregate FILE [FILE...] merges shard_window \
                 partials from JSONL streams, quarantining malformed lines"
            );
            eprintln!(
                "fleet:     gapp serve --listen SOCK [--producers N] [--top K] \
                 [--horizon W] [--compact-base B] merges live producers \
                 started with gapp live ... --stream SOCK into one session"
            );
            eprintln!(
                "output:    profile/live take --format text|json|jsonl and \
                 --output FILE (default: text on stdout)"
            );
            eprintln!(
                "scenario:  gapp scenario run|matrix FILE [--seed N] \
                 [--format text|json|jsonl] [--output FILE] executes a \
                 scenarios/*.json spec and scores classify() against the \
                 injected ground truth"
            );
            eprintln!("           (repeat --app to profile several applications system-wide;");
            eprintln!(
                "            transport is per-CPU ring shards — --shards defaults to the \
                 CPU count, --shards 1 is one shared ring)"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_run(args: &Args, threads: usize, seed: u64) -> anyhow::Result<()> {
    let name = args.opt_str("app", "blackscholes");
    let app = apps::by_name(&name, threads, seed)
        .ok_or_else(|| anyhow::anyhow!("unknown app {name:?} (try list-apps)"))?;
    let (end, kernel) = run_unprofiled(&app, KernelConfig::default())?;
    println!(
        "{name}: {:.2} ms simulated | {} switches | {} wakeups | {} threads",
        end as f64 / 1e6,
        kernel.stats.switches,
        kernel.stats.wakeups,
        app.num_threads()
    );
    Ok(())
}

/// Shared `GappConfig` flags (`profile` and `live`), validated at parse
/// time: zero values get a real error naming the flag, and `--format`
/// is restricted to the sink backends that exist.
fn gapp_config_from(args: &Args) -> anyhow::Result<GappConfig> {
    let mut gcfg = GappConfig::default();
    if let Some(nmin) = args.get("nmin") {
        gcfg.nmin = Some(nmin.parse()?);
    }
    let bad = |e: String| anyhow::anyhow!(e);
    gcfg.dt = args.opt_min1("dt-us", gcfg.dt / 1000).map_err(bad)? * 1000;
    gcfg.top_n = args.opt_min1("top", gcfg.top_n as u64).map_err(bad)? as usize;
    gcfg.ring_capacity =
        args.opt_min1("ring-capacity", gcfg.ring_capacity as u64).map_err(bad)? as usize;
    if args.get("shards").is_some() {
        gcfg.shards = Some(args.opt_min1("shards", 0).map_err(bad)? as usize);
    }
    gcfg.lane_threads =
        args.opt_min1("lane-threads", gcfg.lane_threads as u64).map_err(bad)? as usize;
    let merge = args
        .opt_choice("merge", &MergeStrategy::NAMES, gcfg.merge.name())
        .map_err(bad)?;
    gcfg.merge = MergeStrategy::from_name(&merge).expect("opt_choice vetted the name");
    let format = args
        .opt_choice("format", &ReportFormat::NAMES, ReportFormat::Text.name())
        .map_err(bad)?;
    gcfg.format = ReportFormat::from_name(&format).expect("opt_choice vetted the name");
    let overflow = args
        .opt_choice("on-overflow", &OverflowPolicy::NAMES, gcfg.on_overflow.name())
        .map_err(bad)?;
    gcfg.on_overflow =
        OverflowPolicy::from_name(&overflow).expect("opt_choice vetted the name");
    if args.get("compact-base").is_some() {
        let b = args.opt_min1("compact-base", 0).map_err(bad)? as usize;
        anyhow::ensure!(
            b >= 2,
            "--compact-base must be >= 2 (got {b}); a base-{b} pyramid cannot \
             spread windows across a tier level"
        );
        gcfg.compact_base = Some(b);
    }
    if args.get("decay-half-life-us").is_some() {
        gcfg.decay_half_life_us =
            Some(args.opt_min1("decay-half-life-us", 0).map_err(bad)?);
    }
    gcfg.output = args.get("output").map(String::from);
    Ok(gcfg)
}

/// Shared durability flags (`profile` and `live`): checkpointing,
/// resume, and fault injection, applied to the session builder.
fn apply_durability<'a>(
    args: &Args,
    mut session: Session<'a>,
) -> anyhow::Result<Session<'a>> {
    if let Some(path) = args.get("checkpoint") {
        session = session.checkpoint(path);
    }
    let every = args
        .opt_min1("checkpoint-every", 1)
        .map_err(|e| anyhow::anyhow!(e))?;
    session = session.checkpoint_every(every);
    if let Some(path) = args.get("resume") {
        session = session.restore(path);
    }
    if let Some(path) = args.get("fault-plan") {
        let plan = FaultPlan::load(path).map_err(|e| anyhow::anyhow!(e))?;
        session = session.fault_plan(plan);
    }
    Ok(session)
}

/// Open the sink the config asks for: `--format` picks the backend,
/// `--output` the destination (stdout when absent).
fn report_sink(gcfg: &GappConfig) -> anyhow::Result<Box<dyn ReportSink>> {
    let w: Box<dyn std::io::Write> = match &gcfg.output {
        Some(path) => Box::new(
            std::fs::File::create(path)
                .with_context(|| format!("cannot create --output {path:?}"))?,
        ),
        None => Box::new(std::io::stdout()),
    };
    Ok(sink::for_writer(gcfg.format, w))
}

fn cmd_profile(args: &Args, engine: EngineKind, threads: usize, seed: u64) -> anyhow::Result<()> {
    anyhow::ensure!(
        args.get("stream").is_none(),
        "--stream is a live-mode transport (batch sessions close no windows to \
         stream); use gapp live --stream PATH"
    );
    anyhow::ensure!(
        args.get("listen").is_none(),
        "--listen belongs to gapp serve (the fleet aggregation service); \
         profile does not accept connections"
    );
    let name = args.opt_str("app", "blackscholes");
    let app = apps::by_name(&name, threads, seed)
        .ok_or_else(|| anyhow::anyhow!("unknown app {name:?} (try list-apps)"))?;
    let gcfg = gapp_config_from(args)?;
    let sink = report_sink(&gcfg)?;
    let session = Session::builder(engine.make()?)
        .kernel(KernelConfig::default())
        .config(gcfg)
        .app(&app)
        .sink(sink);
    apply_durability(args, session)?.run()?;
    Ok(())
}

/// The streaming analyzer: epoch-windowed per-window top-K, optionally
/// over several applications sharing the kernel (system-wide mode).
/// All rendering — per-window lines, the final header, the cumulative
/// sketch, the lossy-run note — happens in the attached sink.
fn cmd_live(args: &Args, engine: EngineKind, threads: usize, seed: u64) -> anyhow::Result<()> {
    let mut names: Vec<String> =
        args.get_all("app").into_iter().map(String::from).collect();
    if names.is_empty() {
        names.push("mysql".to_string());
    }
    let apps: Vec<App> = names
        .iter()
        .map(|n| {
            apps::by_name(n, threads, seed)
                .ok_or_else(|| anyhow::anyhow!("unknown app {n:?} (try list-apps)"))
        })
        .collect::<anyhow::Result<_>>()?;
    let mut gcfg = gapp_config_from(args)?;
    gcfg.stack_lru = args.flag("lru");
    let bad = |e: String| anyhow::anyhow!(e);
    // --stream implies --shard-partials: a fleet producer has nothing
    // to ship without its per-shard window partials.
    let stream = args.get("stream").map(String::from);
    let lcfg = LiveConfig {
        window_ns: args.opt_min1("window-us", 5000).map_err(bad)? * 1000,
        top_k: args.opt_min1("top", 5).map_err(bad)? as usize,
        sketch_entries: args.opt_min1("sketch", 64).map_err(bad)? as usize,
        shard_partials: args.flag("shard-partials") || stream.is_some(),
    };
    let sink = report_sink(&gcfg)?;
    let mut session = Session::builder(engine.make()?)
        .kernel(KernelConfig::default())
        .config(gcfg)
        .live(lcfg)
        .sink(sink);
    if let Some(path) = &stream {
        session = session.sink(StreamSink::connect(path)?);
    }
    for app in &apps {
        session = session.app(app);
    }
    apply_durability(args, session)?.run()?;
    Ok(())
}

/// Merge `shard_window` partials from one or more JSONL files (one
/// producer per file) and print the fleet-aggregation report: the
/// one-shot special case of `gapp serve`. `symbols` events, when the
/// capture carries them, symbolize the report; captures without them
/// fall back to raw stack ids, byte-identical to the historical
/// aggregator. Malformed lines are quarantined per producer and
/// surfaced in the report; unreadable files are hard errors.
fn cmd_aggregate(args: &Args) -> anyhow::Result<()> {
    let files = &args.positional[1..];
    anyhow::ensure!(
        !files.is_empty(),
        "aggregate needs at least one JSONL file (gapp aggregate FILE [FILE...])"
    );
    let mut fleet = FleetMerge::new();
    for f in files {
        fleet.ingest_file(f)?;
    }
    let top = args
        .opt_min1("top", 10)
        .map_err(|e| anyhow::anyhow!(e))? as usize;
    print!("{}", fleet.render(top));
    Ok(())
}

/// `gapp serve --listen PATH`: the fleet aggregation service. Accepts
/// `--producers` connections from `gapp live --stream PATH` sessions,
/// re-interns their stack-id namespaces through one global map, folds
/// their windows under a bounded reorder horizon and re-emits ONE
/// merged schema-1 session through the chosen sink (`--format`,
/// default jsonl; `--output`, default stdout). The final fleet report
/// prints to stdout when the service finishes.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let listen = args
        .get("listen")
        .ok_or_else(|| {
            anyhow::anyhow!("serve needs --listen PATH (a Unix socket address)")
        })?
        .to_string();
    let bad = |e: String| anyhow::anyhow!(e);
    let compact_base = if args.get("compact-base").is_some() {
        let b = args.opt_min1("compact-base", 0).map_err(bad)? as usize;
        anyhow::ensure!(
            b >= 2,
            "--compact-base must be >= 2 (got {b}); a base-{b} pyramid cannot \
             spread windows across a tier level"
        );
        Some(b)
    } else {
        None
    };
    let cfg = ServeConfig {
        listen,
        producers: args.opt_min1("producers", 1).map_err(bad)? as usize,
        top: args.opt_min1("top", 10).map_err(bad)? as usize,
        horizon: args.opt_min1("horizon", 8).map_err(bad)?,
        compact_base,
    };
    let format = args
        .opt_choice("format", &ReportFormat::NAMES, ReportFormat::Jsonl.name())
        .map_err(bad)?;
    let format = ReportFormat::from_name(&format).expect("opt_choice vetted the name");
    let w: Box<dyn std::io::Write> = match args.get("output") {
        Some(path) => Box::new(
            std::fs::File::create(path)
                .with_context(|| format!("cannot create --output {path:?}"))?,
        ),
        None => Box::new(std::io::stdout()),
    };
    let mut sinks: Vec<Box<dyn ReportSink>> = vec![sink::for_writer(format, w)];
    let report = gapp::fleet::serve(&cfg, &mut sinks)?;
    print!("{report}");
    Ok(())
}

/// `gapp scenario run|matrix FILE`: execute a declarative scenario
/// spec and score the classifier against its injected ground truth.
/// `run` executes the base case with the full report stream plus an
/// inline scorecard; `matrix` sweeps the spec's seeds × thread-counts
/// silently and emits one scorecard per case plus the aggregate.
fn cmd_scenario(args: &Args, engine: EngineKind) -> anyhow::Result<()> {
    let usage = "usage: gapp scenario run|matrix FILE [--seed N] \
                 [--format text|json|jsonl] [--output FILE]";
    let verb = args.positional.get(1).map(String::as_str);
    let file = match (verb, args.positional.get(2)) {
        (Some("run") | Some("matrix"), Some(f)) => f,
        _ => anyhow::bail!("{usage}"),
    };
    let mut sc = Scenario::load(file).map_err(|e| anyhow::anyhow!(e))?;
    if let Some(s) = args.get("seed") {
        sc.seed = s
            .parse()
            .map_err(|e| anyhow::anyhow!("bad --seed {s:?}: {e}"))?;
    }
    let gcfg = gapp_config_from(args)?;
    let mut sink = report_sink(&gcfg)?;
    match verb {
        Some("run") => {
            let case = scenario::Case {
                index: 0,
                seed: sc.seed,
                threads: None,
            };
            scenario::run_case(&sc, &case, engine.make()?, Some(sink))?;
        }
        _ => {
            // Validate the backend once up front; per-case engines are
            // then infallible (artifact presence cannot change mid-run).
            engine.make()?;
            scenario_matrix::run_matrix(
                &sc,
                &|| engine.make().expect("backend validated above"),
                sink.as_mut(),
            )?;
        }
    }
    Ok(())
}

fn cmd_all(engine: EngineKind, threads: usize, seed: u64) -> anyhow::Result<()> {
    println!("{}", table2::render(&table2::run(engine, threads, seed)?));
    println!("{}", fig3::render(&fig3::run(engine, threads.min(32), seed)?));
    println!("{}", fig4::render(&fig4::run(engine, seed)?));
    println!("{}", fig5::render(&fig5::run(engine, seed)?));
    println!("{}", fig6::render(&fig6::run(engine, seed)?));
    println!("{}", fig7::render(&fig7::run(engine, seed)?));
    println!("{}", dedup_alloc::render(&dedup_alloc::run(engine, seed)?));
    println!("{}", sensitivity::render(&sensitivity::run(engine, seed)?));
    println!("{}", overhead::render(&overhead::run(engine, threads, seed)?));
    println!("{}", baselines_cmp::render(&baselines_cmp::run(engine, seed)?));
    Ok(())
}
