//! Minimal command-line parser (clap is unavailable in the offline
//! registry). Supports `--flag`, `--key value`, `--key=value`, repeated
//! options (`--app a --app b`) and positional arguments, with typed
//! getters and a usage renderer.

use std::collections::BTreeMap;

/// Parsed arguments: positionals in order plus `--key [value]` options.
/// A repeated key keeps every value in order; single-value getters
/// return the last occurrence (so overrides behave as expected).
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    opts: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.push_opt(k, v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.push_opt(rest, v);
                } else {
                    args.push_opt(rest, "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    fn push_opt(&mut self, key: &str, value: String) {
        self.opts.entry(key.to_string()).or_default().push(value);
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Raw option value (last occurrence when repeated).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts
            .get(key)
            .and_then(|v| v.last())
            .map(|s| s.as_str())
    }

    /// Every value given for `key`, in order (`--app a --app b`).
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.opts
            .get(key)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    /// Boolean flag: present (with any value other than "false") → true.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some(v) if v != "false")
    }

    /// Typed option with default. A malformed value is a hard error
    /// naming the flag (it used to print "using default" and then exit
    /// anyway — a lie in the message).
    pub fn opt<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("error: invalid value for --{key}: {v:?}");
                std::process::exit(2);
            }),
            None => default,
        }
    }

    /// Numeric option that must be >= 1 when present. Zero is a
    /// configuration error, not a request (`--top 0` would report
    /// nothing, `--window-us 0` would never close a window, `--shards 0`
    /// has no transport) — so it is rejected at parse time with a real
    /// error naming the flag, instead of silently misbehaving deeper in
    /// the pipeline.
    pub fn opt_min1(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.parse::<u64>() {
                Ok(0) => Err(format!("--{key} must be >= 1 (got 0)")),
                Ok(n) => Ok(n),
                Err(_) => Err(format!("--{key} expects a positive integer (got {v:?})")),
            },
        }
    }

    /// String option with default.
    pub fn opt_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// String option restricted to a fixed choice set (`--format
    /// text|json|jsonl`). Anything outside the set is a parse-time
    /// error naming the flag and the accepted values — not a silent
    /// fallback to the default.
    pub fn opt_choice(
        &self,
        key: &str,
        choices: &[&str],
        default: &str,
    ) -> Result<String, String> {
        debug_assert!(choices.contains(&default));
        match self.get(key) {
            None => Ok(default.to_string()),
            Some(v) if choices.contains(&v) => Ok(v.to_string()),
            Some(v) => Err(format!(
                "--{key} must be one of {} (got {v:?})",
                choices.join("|")
            )),
        }
    }

    /// First positional (the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_opts() {
        let a = parse(&["profile", "--app", "dedup", "--seed=7", "--verbose"]);
        assert_eq!(a.subcommand(), Some("profile"));
        assert_eq!(a.get("app"), Some("dedup"));
        assert_eq!(a.opt::<u64>("seed", 0), 7);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["run"]);
        assert_eq!(a.opt::<u32>("threads", 64), 64);
        assert_eq!(a.opt_str("app", "blackscholes"), "blackscholes");
    }

    #[test]
    fn eq_form_and_space_form_agree() {
        let a = parse(&["--x=1", "--y", "2"]);
        assert_eq!(a.opt::<i32>("x", 0), 1);
        assert_eq!(a.opt::<i32>("y", 0), 2);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["cmd", "--fast"]);
        assert!(a.flag("fast"));
    }

    #[test]
    fn negative_number_values() {
        let a = parse(&["--delta", "-3"]);
        assert_eq!(a.opt::<i64>("delta", 0), -3);
    }

    #[test]
    fn opt_min1_rejects_zero_and_garbage_with_real_errors() {
        let a = parse(&["live", "--top", "0", "--window-us", "5000", "--shards", "x"]);
        let err = a.opt_min1("top", 5).unwrap_err();
        assert!(err.contains("--top"), "{err}");
        assert!(err.contains(">= 1"), "{err}");
        assert_eq!(a.opt_min1("window-us", 5000), Ok(5000));
        assert_eq!(a.opt_min1("absent", 7), Ok(7));
        let err = a.opt_min1("shards", 1).unwrap_err();
        assert!(err.contains("--shards"), "{err}");
    }

    #[test]
    fn opt_choice_accepts_the_set_and_rejects_the_rest() {
        let a = parse(&["profile", "--format", "json"]);
        assert_eq!(
            a.opt_choice("format", &["text", "json", "jsonl"], "text"),
            Ok("json".to_string())
        );
        // Absent → default.
        assert_eq!(
            a.opt_choice("other", &["x", "y"], "x"),
            Ok("x".to_string())
        );
        let a = parse(&["profile", "--format", "xml"]);
        let err = a
            .opt_choice("format", &["text", "json", "jsonl"], "text")
            .unwrap_err();
        assert!(err.contains("--format"), "{err}");
        assert!(err.contains("text|json|jsonl"), "{err}");
        assert!(err.contains("xml"), "{err}");
    }

    #[test]
    fn repeated_options_collect_in_order() {
        let a = parse(&["live", "--app", "mysql", "--app=dedup", "--app", "vips"]);
        assert_eq!(a.get_all("app"), vec!["mysql", "dedup", "vips"]);
        // Single-value getter sees the last occurrence.
        assert_eq!(a.get("app"), Some("vips"));
        assert_eq!(a.get_all("missing"), Vec::<&str>::new());
    }
}
