//! Small statistics toolkit for experiment reporting: summaries,
//! percentiles, coefficient of variation, and fixed-width table printing.

/// Summary statistics over a sample.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub sd: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary of `xs` (empty input → all zeros).
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(1) as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            sd: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }

    /// Coefficient of variation (sd/mean); 0 for a zero mean.
    pub fn cv(&self) -> f64 {
        if self.mean.abs() < f64::EPSILON {
            0.0
        } else {
            self.sd / self.mean
        }
    }
}

/// Percentile by linear interpolation over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Relative change `(new - old) / old` in percent.
pub fn pct_change(old: f64, new: f64) -> f64 {
    if old.abs() < f64::EPSILON {
        0.0
    } else {
        (new - old) / old * 100.0
    }
}

/// Geometric mean of positive values (ignores non-positive entries).
pub fn geomean(xs: &[f64]) -> f64 {
    let logs: Vec<f64> = xs.iter().filter(|x| **x > 0.0).map(|x| x.ln()).collect();
    if logs.is_empty() {
        0.0
    } else {
        (logs.iter().sum::<f64>() / logs.len() as f64).exp()
    }
}

/// Format nanoseconds human-readably (ns/µs/ms/s).
pub fn fmt_ns(ns: u64) -> String {
    match ns {
        0..=999 => format!("{ns} ns"),
        1_000..=999_999 => format!("{:.1} µs", ns as f64 / 1e3),
        1_000_000..=999_999_999 => format!("{:.1} ms", ns as f64 / 1e6),
        _ => format!("{:.3} s", ns as f64 / 1e9),
    }
}

/// Format bytes human-readably (B/KB/MB/GB).
pub fn fmt_bytes(b: u64) -> String {
    const K: u64 = 1024;
    match b {
        0..=1023 => format!("{b} B"),
        _ if b < K * K => format!("{:.1} KB", b as f64 / K as f64),
        _ if b < K * K * K => format!("{:.1} MB", b as f64 / (K * K) as f64),
        _ => format!("{:.2} GB", b as f64 / (K * K * K) as f64),
    }
}

/// A minimal fixed-width table printer for experiment output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column auto-width, markdown-pipe style.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], width: &[usize], out: &mut String| {
            out.push('|');
            for (i, c) in cells.iter().enumerate() {
                out.push_str(&format!(" {:<w$} |", c, w = width[i]));
            }
            out.push('\n');
        };
        line(&self.headers, &width, &mut out);
        out.push('|');
        for w in &width {
            out.push_str(&format!("{}-|", "-".repeat(w + 1)));
        }
        out.push('\n');
        for row in &self.rows {
            line(row, &width, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn pct_change_signs() {
        assert!((pct_change(100.0, 122.0) - 22.0).abs() < 1e-9);
        assert!((pct_change(100.0, 86.0) + 14.0).abs() < 1e-9);
    }

    #[test]
    fn geomean_positive_only() {
        let g = geomean(&[1.0, 100.0, 0.0, -5.0]);
        assert!((g - 10.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(12), "12 ns");
        assert_eq!(fmt_ns(1_500), "1.5 µs");
        assert_eq!(fmt_ns(2_500_000), "2.5 ms");
        assert_eq!(fmt_ns(3_000_000_000), "3.000 s");
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(10), "10 B");
        assert_eq!(fmt_bytes(2048), "2.0 KB");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["app", "o/h"]);
        t.row(&["dedup".into(), "12%".into()]);
        let r = t.render();
        assert!(r.contains("| app   | o/h |"));
        assert!(r.contains("| dedup | 12% |"));
    }

    #[test]
    fn cv_of_constant_zero() {
        let s = Summary::of(&[5.0, 5.0, 5.0]);
        assert!(s.cv() < 1e-12);
    }
}
