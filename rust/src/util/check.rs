//! Property-testing mini-framework (proptest is unavailable offline).
//!
//! A property is a closure over a [`Prng`]-driven generator; the runner
//! executes it for N seeds and, on failure, re-runs with the failing seed
//! reported so the case is reproducible:
//!
//! ```ignore
//! property("cmetric conservation", 200, |rng| {
//!     let batch = gen_batch(rng);
//!     check(batch.invariant_holds(), "invariant");
//! });
//! ```

use super::prng::Prng;

/// Number of cases per property unless the env overrides it.
pub fn default_cases() -> u64 {
    std::env::var("CHECK_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(128)
}

/// Run `f` for `cases` deterministic seeds; panic with the seed on failure.
pub fn property<F: Fn(&mut Prng)>(name: &str, cases: u64, f: F) {
    let base = 0xC0FFEE_u64;
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Prng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property {name:?} failed at case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Shrink helper: given a failing vector input, try removing chunks while
/// the predicate still fails; returns a (locally) minimal failing input.
pub fn shrink_vec<T: Clone, P: Fn(&[T]) -> bool>(input: &[T], fails: P) -> Vec<T> {
    let mut cur: Vec<T> = input.to_vec();
    let mut chunk = cur.len() / 2;
    while chunk >= 1 {
        let mut i = 0;
        while i + chunk <= cur.len() {
            let mut candidate = cur.clone();
            candidate.drain(i..i + chunk);
            if fails(&candidate) {
                cur = candidate;
            } else {
                i += chunk;
            }
        }
        if chunk == 1 {
            break;
        }
        chunk /= 2;
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_passes_quietly() {
        property("sum commutative", 50, |rng| {
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn property_reports_seed_on_failure() {
        property("always fails", 3, |_| {
            panic!("boom");
        });
    }

    #[test]
    fn shrink_finds_minimal() {
        // Failing predicate: contains a 7.
        let input: Vec<u32> = (0..100).collect();
        let min = shrink_vec(&input, |v| v.contains(&7));
        assert_eq!(min, vec![7]);
    }

    #[test]
    fn deterministic_case_seeds() {
        let seen: Vec<u64> = Vec::new();
        property("record", 5, |rng| {
            seen.len(); // no-op; seeds derived deterministically
            let _ = rng.next_u64();
        });
        property("record2", 5, |rng| {
            seen.len();
            let _ = rng.next_u64();
        });
    }
}
