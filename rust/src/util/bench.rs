//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Usage inside a `[[bench]] harness = false` target:
//!
//! ```ignore
//! let mut b = Bench::from_env("ringbuf");
//! b.bench("push_pop", || { /* work */ });
//! b.finish();
//! ```
//!
//! Each benchmark is warmed up, then run in timed batches until both a
//! minimum sample count and a minimum measuring time are reached; the
//! report prints mean/p50/p99 per iteration plus throughput when the
//! caller declares per-iteration items.

use std::time::{Duration, Instant};

use super::stats::{fmt_ns, Summary};

/// Harness configuration (override via env: BENCH_MIN_SAMPLES, BENCH_MIN_MS).
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup_iters: u64,
    pub min_samples: usize,
    pub min_time: Duration,
    pub batch: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_samples: 20,
            min_time: Duration::from_millis(300),
            batch: 1,
        }
    }
}

/// One benchmark group, printing rows as it goes.
pub struct Bench {
    group: String,
    cfg: BenchConfig,
    results: Vec<(String, Summary)>,
    filter: Option<String>,
}

impl Bench {
    pub fn new(group: &str, cfg: BenchConfig) -> Bench {
        println!("\n== bench group: {group} ==");
        Bench {
            group: group.to_string(),
            cfg,
            results: Vec::new(),
            filter: None,
        }
    }

    /// Construct honoring env overrides and an optional name filter in
    /// argv[1] (mirrors `cargo bench -- <filter>`).
    pub fn from_env(group: &str) -> Bench {
        let mut cfg = BenchConfig::default();
        if let Ok(v) = std::env::var("BENCH_MIN_SAMPLES") {
            if let Ok(n) = v.parse() {
                cfg.min_samples = n;
            }
        }
        if let Ok(v) = std::env::var("BENCH_MIN_MS") {
            if let Ok(n) = v.parse() {
                cfg.min_time = Duration::from_millis(n);
            }
        }
        let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
        let mut b = Bench::new(group, cfg);
        b.filter = filter;
        b
    }

    fn skip(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => !name.contains(f.as_str()) && !self.group.contains(f.as_str()),
            None => false,
        }
    }

    /// Time `f`, reporting per-iteration latency.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Option<Summary> {
        self.bench_items(name, 1, move || {
            f();
        })
    }

    /// Time `f`, additionally reporting items/second given `items` units of
    /// work per call.
    pub fn bench_items<F: FnMut()>(
        &mut self,
        name: &str,
        items: u64,
        mut f: F,
    ) -> Option<Summary> {
        if self.skip(name) {
            return None;
        }
        for _ in 0..self.cfg.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.cfg.min_samples || start.elapsed() < self.cfg.min_time
        {
            let t0 = Instant::now();
            for _ in 0..self.cfg.batch {
                f();
            }
            let per = t0.elapsed().as_nanos() as f64 / self.cfg.batch as f64;
            samples.push(per);
            if samples.len() >= 10_000 {
                break;
            }
        }
        let s = Summary::of(&samples);
        let mut line = format!(
            "  {:<40} mean {:>12}  p50 {:>12}  p99 {:>12}  (n={})",
            name,
            fmt_ns(s.mean as u64),
            fmt_ns(s.p50 as u64),
            fmt_ns(s.p99 as u64),
            s.n
        );
        if items > 1 {
            let per_sec = items as f64 / (s.mean / 1e9);
            line.push_str(&format!("  {:.2} Mitems/s", per_sec / 1e6));
        }
        println!("{line}");
        self.results.push((name.to_string(), s.clone()));
        Some(s)
    }

    /// Print a closing line; returns collected summaries for programmatic
    /// use (e.g. regression assertions in the perf pass).
    pub fn finish(self) -> Vec<(String, Summary)> {
        println!("== end group: {} ({} benchmarks) ==", self.group, self.results.len());
        self.results
    }
}

/// Opaque value sink to prevent the optimizer deleting benched work
/// (std::hint::black_box is stable but this keeps call sites tidy).
#[inline]
pub fn sink<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> BenchConfig {
        BenchConfig {
            warmup_iters: 1,
            min_samples: 3,
            min_time: Duration::from_millis(1),
            batch: 1,
        }
    }

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bench::new("test", quick_cfg());
        let s = b
            .bench("noop_sum", || {
                let mut acc = 0u64;
                for i in 0..100 {
                    acc = acc.wrapping_add(i);
                }
                sink(acc);
            })
            .unwrap();
        assert!(s.n >= 3);
        assert!(s.mean > 0.0);
        let results = b.finish();
        assert_eq!(results.len(), 1);
    }

    #[test]
    fn items_throughput_positive() {
        let mut b = Bench::new("test2", quick_cfg());
        let s = b
            .bench_items("items", 64, || {
                sink(1 + 1);
            })
            .unwrap();
        assert!(s.mean > 0.0);
    }
}
