//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Usage inside a `[[bench]] harness = false` target:
//!
//! ```ignore
//! let mut b = Bench::from_env("ringbuf");
//! b.bench("push_pop", || { /* work */ });
//! b.finish();
//! ```
//!
//! Each benchmark is warmed up, then run in timed batches until both a
//! minimum sample count and a minimum measuring time are reached; the
//! report prints mean/p50/p99 per iteration plus throughput when the
//! caller declares per-iteration items.

use std::time::{Duration, Instant};

use super::stats::{fmt_ns, Summary};

/// Harness configuration (override via env: BENCH_MIN_SAMPLES,
/// BENCH_MIN_MS, BENCH_WARMUP).
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup_iters: u64,
    pub min_samples: usize,
    pub min_time: Duration,
    pub batch: u64,
    /// Write `BENCH_<group>.json` at the repo root on `finish()` so the
    /// perf trajectory is tracked across PRs (disable for unit tests).
    pub emit_json: bool,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_samples: 20,
            min_time: Duration::from_millis(300),
            batch: 1,
            emit_json: true,
        }
    }
}

/// One benchmark group, printing rows as it goes.
pub struct Bench {
    group: String,
    cfg: BenchConfig,
    results: Vec<BenchResult>,
    filter: Option<String>,
}

/// One finished benchmark: name, declared per-iteration items, summary.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub items: u64,
    pub summary: Summary,
}

impl Bench {
    pub fn new(group: &str, cfg: BenchConfig) -> Bench {
        println!("\n== bench group: {group} ==");
        Bench {
            group: group.to_string(),
            cfg,
            results: Vec::new(),
            filter: None,
        }
    }

    /// Construct honoring env overrides and an optional name filter in
    /// argv[1] (mirrors `cargo bench -- <filter>`).
    pub fn from_env(group: &str) -> Bench {
        let mut cfg = BenchConfig::default();
        if let Ok(v) = std::env::var("BENCH_MIN_SAMPLES") {
            if let Ok(n) = v.parse() {
                cfg.min_samples = n;
            }
        }
        if let Ok(v) = std::env::var("BENCH_MIN_MS") {
            if let Ok(n) = v.parse() {
                cfg.min_time = Duration::from_millis(n);
            }
        }
        if let Ok(v) = std::env::var("BENCH_WARMUP") {
            if let Ok(n) = v.parse() {
                cfg.warmup_iters = n;
            }
        }
        let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
        let mut b = Bench::new(group, cfg);
        b.filter = filter;
        b
    }

    fn skip(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => !name.contains(f.as_str()) && !self.group.contains(f.as_str()),
            None => false,
        }
    }

    /// Time `f`, reporting per-iteration latency.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Option<Summary> {
        self.bench_items(name, 1, move || {
            f();
        })
    }

    /// Time `f`, additionally reporting items/second given `items` units of
    /// work per call.
    pub fn bench_items<F: FnMut()>(
        &mut self,
        name: &str,
        items: u64,
        mut f: F,
    ) -> Option<Summary> {
        if self.skip(name) {
            return None;
        }
        for _ in 0..self.cfg.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.cfg.min_samples || start.elapsed() < self.cfg.min_time
        {
            let t0 = Instant::now();
            for _ in 0..self.cfg.batch {
                f();
            }
            let per = t0.elapsed().as_nanos() as f64 / self.cfg.batch as f64;
            samples.push(per);
            if samples.len() >= 10_000 {
                break;
            }
        }
        let s = Summary::of(&samples);
        let mut line = format!(
            "  {:<40} mean {:>12}  p50 {:>12}  p99 {:>12}  (n={})",
            name,
            fmt_ns(s.mean as u64),
            fmt_ns(s.p50 as u64),
            fmt_ns(s.p99 as u64),
            s.n
        );
        if items > 1 {
            let per_sec = items as f64 / (s.mean / 1e9);
            line.push_str(&format!("  {:.2} Mitems/s", per_sec / 1e6));
        }
        println!("{line}");
        self.results.push(BenchResult {
            name: name.to_string(),
            items,
            summary: s.clone(),
        });
        Some(s)
    }

    /// Print a closing line and (unless disabled) write the
    /// machine-readable `BENCH_<group>.json`; returns collected
    /// summaries for programmatic use (e.g. regression assertions in
    /// the perf pass).
    pub fn finish(self) -> Vec<(String, Summary)> {
        println!("== end group: {} ({} benchmarks) ==", self.group, self.results.len());
        if self.filter.is_some() {
            // A filtered run covers a subset; writing the JSON would make
            // PR-to-PR diffs of the trajectory file compare different
            // bench sets, so skip emission.
            println!("(name filter active: not writing BENCH_{}.json)", self.group);
        } else if self.cfg.emit_json && !self.results.is_empty() {
            let path = json_out_path(&self.group);
            match std::fs::write(&path, render_json(&self.group, &self.results)) {
                Ok(()) => println!("wrote {}", path.display()),
                Err(e) => eprintln!("could not write {}: {e}", path.display()),
            }
        }
        self.results
            .into_iter()
            .map(|r| (r.name, r.summary))
            .collect()
    }
}

/// `BENCH_<group>.json` goes to $BENCH_JSON_DIR when set, else the repo
/// root (nearest ancestor holding ROADMAP.md), else the current dir.
/// Cargo runs bench binaries with cwd = package root, so the repo root
/// is normally one level up.
fn json_out_path(group: &str) -> std::path::PathBuf {
    let file = format!("BENCH_{group}.json");
    if let Some(dir) = std::env::var_os("BENCH_JSON_DIR") {
        return std::path::PathBuf::from(dir).join(file);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    for _ in 0..4 {
        if dir.join("ROADMAP.md").exists() {
            return dir.join(file);
        }
        if !dir.pop() {
            break;
        }
    }
    std::path::PathBuf::from(file)
}

/// Hand-rolled JSON (no serde in the offline registry): a stable schema
/// the perf pass diffs across PRs.
fn render_json(group: &str, results: &[BenchResult]) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"group\": \"{group}\",\n"));
    out.push_str("  \"schema\": 1,\n");
    out.push_str("  \"benches\": [\n");
    for (i, r) in results.iter().enumerate() {
        let s = &r.summary;
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"n\": {}, \"mean_ns\": {:.1}, \"p50_ns\": {:.1}, \"p99_ns\": {:.1}, \"items\": {}, \"items_per_sec\": {:.1}}}{}\n",
            r.name,
            s.n,
            s.mean,
            s.p50,
            s.p99,
            r.items,
            if s.mean > 0.0 {
                r.items as f64 / (s.mean / 1e9)
            } else {
                0.0
            },
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Opaque value sink to prevent the optimizer deleting benched work
/// (std::hint::black_box is stable but this keeps call sites tidy).
#[inline]
pub fn sink<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> BenchConfig {
        BenchConfig {
            warmup_iters: 1,
            min_samples: 3,
            min_time: Duration::from_millis(1),
            batch: 1,
            emit_json: false,
        }
    }

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bench::new("test", quick_cfg());
        let s = b
            .bench("noop_sum", || {
                let mut acc = 0u64;
                for i in 0..100 {
                    acc = acc.wrapping_add(i);
                }
                sink(acc);
            })
            .unwrap();
        assert!(s.n >= 3);
        assert!(s.mean > 0.0);
        let results = b.finish();
        assert_eq!(results.len(), 1);
    }

    #[test]
    fn items_throughput_positive() {
        let mut b = Bench::new("test2", quick_cfg());
        let s = b
            .bench_items("items", 64, || {
                sink(1 + 1);
            })
            .unwrap();
        assert!(s.mean > 0.0);
    }

    #[test]
    fn json_rendering_is_wellformed() {
        let results = vec![BenchResult {
            name: "push_pop".into(),
            items: 4096,
            summary: Summary::of(&[100.0, 110.0, 120.0]),
        }];
        let j = render_json("hotpath", &results);
        assert!(j.contains("\"group\": \"hotpath\""));
        assert!(j.contains("\"name\": \"push_pop\""));
        assert!(j.contains("\"items\": 4096"));
        assert!(j.trim_end().ends_with('}'));
        // Balanced braces/brackets (cheap well-formedness check).
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }
}
