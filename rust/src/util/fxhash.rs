//! FxHash-style hashing (the rustc / Firefox multiply-rotate hash).
//!
//! `std::collections::HashMap` defaults to SipHash-1-3 with a random
//! per-process seed — robust against adversarial keys, but several times
//! slower than needed for the trusted integer keys on the probe hot path
//! (pids, addresses, stack hashes), and its random seed makes iteration
//! order differ between runs. `FxHasher` is deterministic and compiles
//! to a handful of ALU ops per word, which is what the in-kernel eBPF
//! hash maps cost in the real system.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Multiply-rotate hasher over 64-bit words.
#[derive(Clone, Copy, Debug, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(tail));
        }
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u16(&mut self, v: u16) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }

    #[inline]
    fn write_u128(&mut self, v: u128) {
        self.add(v as u64);
        self.add((v >> 64) as u64);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }

    #[inline]
    fn write_i8(&mut self, v: i8) {
        self.add(v as u64);
    }

    #[inline]
    fn write_i16(&mut self, v: i16) {
        self.add(v as u64);
    }

    #[inline]
    fn write_i32(&mut self, v: i32) {
        self.add(v as u64);
    }

    #[inline]
    fn write_i64(&mut self, v: i64) {
        self.add(v as u64);
    }

    #[inline]
    fn write_isize(&mut self, v: isize) {
        self.add(v as u64);
    }
}

/// `BuildHasher` for [`FxHasher`] (zero-sized, `Default`-constructible).
pub type BuildFxHasher = BuildHasherDefault<FxHasher>;

/// `HashMap` keyed with [`FxHasher`] — deterministic iteration for a
/// fixed insertion sequence, and fast on integer keys.
pub type FxHashMap<K, V> = HashMap<K, V, BuildFxHasher>;

/// `HashSet` twin of [`FxHashMap`].
pub type FxHashSet<T> = HashSet<T, BuildFxHasher>;

/// Hash one `u64` slice (length-suffixed) — the stack-trace key used by
/// `ebpf::stackmap`.
#[inline]
pub fn hash_words(words: &[u64]) -> u64 {
    let mut h = FxHasher::default();
    for &w in words {
        h.write_u64(w);
    }
    h.write_usize(words.len());
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = FxHasher::default();
        let mut b = FxHasher::default();
        a.write_u64(0xDEAD_BEEF);
        b.write_u64(0xDEAD_BEEF);
        assert_eq!(a.finish(), b.finish());
        assert_ne!(a.finish(), 0);
    }

    #[test]
    fn distinguishes_values_and_lengths() {
        assert_ne!(hash_words(&[1, 2]), hash_words(&[2, 1]));
        assert_ne!(hash_words(&[1]), hash_words(&[1, 0]));
        assert_ne!(hash_words(&[]), hash_words(&[0]));
    }

    #[test]
    fn map_and_set_work() {
        let mut m: FxHashMap<u64, u64> = FxHashMap::default();
        for i in 0..1000 {
            m.insert(i, i * 3);
        }
        assert_eq!(m.get(&500), Some(&1500));
        let s: FxHashSet<u32> = (0..10).collect();
        assert!(s.contains(&7));
    }

    #[test]
    fn byte_writes_cover_tails() {
        let mut a = FxHasher::default();
        a.write(&[1, 2, 3, 4, 5, 6, 7, 8, 9]); // 8-byte chunk + 1 tail
        let mut b = FxHasher::default();
        b.write(&[1, 2, 3, 4, 5, 6, 7, 8]);
        assert_ne!(a.finish(), b.finish());
    }
}
