//! Hand-rolled JSON: a document model, a writer (compact and pretty)
//! and a parser — no external crates (serde is unavailable in the
//! offline registry, like clap/criterion before it).
//!
//! The one design decision that matters: **numbers are stored as their
//! literal text** ([`Json::Num`] holds the digits, not an `f64`). GAPP
//! reports carry `u64` counters (femtosecond CMetric totals, runtime
//! nanoseconds) that exceed 2^53, so routing them through a float —
//! what most small JSON layers do — would silently corrupt them.
//! Keeping the literal makes `u64 → JSON → u64` lossless, and `f64`
//! round-trips exactly too because Rust's `{}` formatting emits the
//! shortest representation that parses back to the same bits.

use std::fmt::Write as _;

/// A JSON document. Object keys keep insertion order (`Vec`, not a
/// map), so serialization is deterministic — the sink golden tests
/// byte-compare emitted documents.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Number as its literal text (lossless for `u64` and `f64`).
    Num(String),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
    /// **Emit-only**: a pre-serialized JSON value spliced verbatim into
    /// the output. The checkpoint writer uses this to append cached
    /// (immutable) tier-entry renderings without re-walking their path
    /// tables every periodic write. The parser never produces `Raw`,
    /// the accessors treat it as opaque (`None`), and the caller owns
    /// the validity of the spliced text — always bytes a previous
    /// `to_compact` produced. Splicing compact text under `to_pretty`
    /// keeps the raw value on one line, which is exactly how the
    /// checkpoint document uses it.
    Raw(String),
}

impl Json {
    pub fn u64(v: u64) -> Json {
        Json::Num(v.to_string())
    }

    pub fn usize(v: usize) -> Json {
        Json::Num(v.to_string())
    }

    /// `f64` → JSON number via Rust's shortest-round-trip formatting.
    /// JSON has no NaN/Infinity; those serialize as `null` (the
    /// truthful "no value here"), debug-asserted since a report should
    /// never produce them.
    pub fn f64(v: f64) -> Json {
        if v.is_finite() {
            Json::Num(format!("{v}"))
        } else {
            debug_assert!(false, "non-finite f64 in JSON output: {v}");
            Json::Null
        }
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    /// Build an object from `(key, value)` pairs, preserving order.
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            fields
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    // ---- accessors (None on type mismatch / missing key) ---------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- writing -------------------------------------------------------

    /// Single-line serialization (JSONL event lines).
    pub fn to_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Indented serialization (whole-session documents meant for eyes
    /// and `python -m json.tool` alike).
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(s) => out.push_str(s),
            Json::Raw(s) => out.push_str(s),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    // ---- parsing -------------------------------------------------------

    /// Parse one JSON document (trailing whitespace allowed, anything
    /// else after it is an error).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Maximum container nesting the parser accepts. The recursive-descent
/// `value`/`array`/`object` cycle consumes host stack per level, so an
/// adversarial depth bomb (`[[[[…`) would otherwise crash the process
/// with a stack overflow — an abort, not a catchable error. 128 levels
/// is far beyond any document the sinks emit (≤ 5) while keeping worst-
/// case recursion bounded.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(c) = self.b.get(self.i) {
            match c {
                b' ' | b'\t' | b'\n' | b'\r' => self.i += 1,
                _ => break,
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.i))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth >= MAX_DEPTH {
            return Err(format!(
                "nesting too deep at byte {} (max {MAX_DEPTH} levels)",
                self.i
            ));
        }
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!(
                "unexpected {:?} at byte {}",
                other.map(|b| b as char),
                self.i
            )),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value(depth + 1)?;
            fields.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let start = self.i;
            // Fast path: run of plain bytes (UTF-8 passes through).
            while let Some(&c) = self.b.get(self.i) {
                if c == b'"' || c == b'\\' {
                    break;
                }
                self.i += 1;
            }
            s.push_str(
                std::str::from_utf8(&self.b[start..self.i])
                    .map_err(|_| "invalid UTF-8 in string".to_string())?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self
                        .peek()
                        .ok_or_else(|| "truncated escape".to_string())?;
                    self.i += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair.
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err("bad low surrogate".to_string());
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| "bad \\u escape".to_string())?,
                            );
                        }
                        c => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                _ => return Err("unterminated string".to_string()),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let end = self.i + 4;
        if end > self.b.len() {
            return Err("truncated \\u escape".to_string());
        }
        let hex = std::str::from_utf8(&self.b[self.i..end])
            .map_err(|_| "bad \\u escape".to_string())?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape".to_string())?;
        self.i = end;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let digits = |p: &mut Parser<'_>| {
            let s = p.i;
            while p.peek().is_some_and(|c| c.is_ascii_digit()) {
                p.i += 1;
            }
            p.i > s
        };
        if !digits(self) {
            return Err(format!("bad number at byte {start}"));
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            if !digits(self) {
                return Err(format!("bad number at byte {start}"));
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            if !digits(self) {
                return Err(format!("bad number at byte {start}"));
            }
        }
        Ok(Json::Num(
            std::str::from_utf8(&self.b[start..self.i])
                .unwrap()
                .to_string(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u64_round_trips_beyond_f64_precision() {
        // 2^63 + 3 is not representable as f64 — the reason Num holds
        // literal text.
        let v = u64::MAX - 2;
        let j = Json::u64(v);
        let parsed = Json::parse(&j.to_compact()).unwrap();
        assert_eq!(parsed.as_u64(), Some(v));
    }

    #[test]
    fn f64_round_trips_exactly() {
        for v in [0.0, 1.5, 0.1 + 0.2, 1e-12, 3.141592653589793, 2.5e17] {
            let parsed = Json::parse(&Json::f64(v).to_compact()).unwrap();
            assert_eq!(parsed.as_f64(), Some(v), "{v} did not round-trip");
        }
    }

    #[test]
    fn strings_escape_and_parse_back() {
        let ugly = "a\"b\\c\nd\te\r f\u{1} — héllo 🦀";
        let parsed = Json::parse(&Json::str(ugly).to_compact()).unwrap();
        assert_eq!(parsed.as_str(), Some(ugly));
    }

    #[test]
    fn nested_document_round_trips_compact_and_pretty() {
        let doc = Json::obj(vec![
            ("schema", Json::u64(1)),
            ("name", Json::str("gapp")),
            ("flag", Json::Bool(true)),
            ("none", Json::Null),
            (
                "xs",
                Json::Arr(vec![Json::u64(1), Json::f64(2.5), Json::str("three")]),
            ),
            ("empty_arr", Json::Arr(vec![])),
            ("empty_obj", Json::Obj(vec![])),
        ]);
        assert_eq!(Json::parse(&doc.to_compact()).unwrap(), doc);
        assert_eq!(Json::parse(&doc.to_pretty()).unwrap(), doc);
        // Key order is preserved (deterministic output).
        let compact = doc.to_compact();
        let schema = compact.find("schema").unwrap();
        let name = compact.find("name").unwrap();
        assert!(schema < name);
    }

    #[test]
    fn accessors_navigate_objects() {
        let doc = Json::parse(r#"{"a": {"b": [10, 20]}, "s": "x"}"#).unwrap();
        assert_eq!(
            doc.get("a").and_then(|a| a.get("b")).and_then(|b| b.as_arr()).map(|v| v.len()),
            Some(2)
        );
        assert_eq!(doc.get("s").and_then(|s| s.as_str()), Some("x"));
        assert!(doc.get("missing").is_none());
    }

    #[test]
    fn parser_handles_unicode_escapes_and_surrogates() {
        let doc = Json::parse(r#""Aé🦀\/""#).unwrap();
        assert_eq!(doc.as_str(), Some("Aé🦀/"));
    }

    #[test]
    fn parser_rejects_garbage() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"unterminated",
            "{\"a\":1} extra", "[01x]", "nan",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn depth_bombs_error_instead_of_overflowing_the_stack() {
        // A pathological `[[[[…` / `{"a":{"a":…` document must come
        // back as a real error, never a process-aborting stack
        // overflow. 4096 levels would need ~4096 recursion frames
        // without the guard.
        let bomb_arr = "[".repeat(4096);
        let err = Json::parse(&bomb_arr).unwrap_err();
        assert!(err.contains("nesting too deep"), "{err}");
        let bomb_obj = "{\"a\":".repeat(4096);
        let err = Json::parse(&bomb_obj).unwrap_err();
        assert!(err.contains("nesting too deep"), "{err}");
        // Deep-but-legal documents still parse: MAX_DEPTH - 1 nested
        // arrays (the innermost value sits at depth MAX_DEPTH - 1).
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH - 1), "]".repeat(MAX_DEPTH - 1));
        assert!(Json::parse(&ok).is_ok());
        // One level past the limit errors.
        let over = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&over).unwrap_err().contains("nesting too deep"));
    }

    #[test]
    fn random_prefixes_and_mutations_of_sink_output_never_panic() {
        // Property test for the robustness contract: feed the parser
        // every prefix and a few hundred random single-byte mutations
        // of a realistic sink document (the shapes `JsonSink`/`JsonlSink`
        // emit). Each call must return Ok or Err — panics and aborts
        // are the only failures.
        let doc = Json::obj(vec![
            ("schema", Json::u64(1)),
            ("event", Json::str("shard_window")),
            (
                "shard_window",
                Json::obj(vec![
                    ("index", Json::u64(3)),
                    ("shard", Json::u64(1)),
                    ("slices", Json::u64(42)),
                    ("drained", Json::u64(128)),
                    ("drops", Json::u64(0)),
                    (
                        "paths",
                        Json::Arr(vec![Json::obj(vec![
                            ("stack_id", Json::u64(7)),
                            ("cm_fs", Json::u64(123_456_789)),
                            ("slices", Json::u64(5)),
                            ("first_seen", Json::u64(1_000_000)),
                        ])]),
                    ),
                ]),
            ),
            ("note", Json::str("héllo \"quoted\" \\ line\nnext")),
            ("ratio", Json::f64(0.0725)),
        ]);
        let text = doc.to_compact();
        assert!(Json::parse(&text).is_ok());
        // Every truncation point (on char boundaries).
        for (i, _) in text.char_indices() {
            let _ = Json::parse(&text[..i]);
        }
        // Deterministic pseudo-random single-byte substitutions; keep
        // the result valid UTF-8 by operating on chars.
        let mut rng = crate::util::Prng::new(0xBADF00D);
        let chars: Vec<char> = text.chars().collect();
        for _ in 0..400 {
            let mut mutated = chars.clone();
            let at = rng.below(mutated.len() as u64) as usize;
            let replacement = [
                '{', '}', '[', ']', '"', ',', ':', '\\', 'x', '0', '9', '\u{1}', 'é',
            ];
            mutated[at] = replacement[rng.below(replacement.len() as u64) as usize];
            let s: String = mutated.into_iter().collect();
            let _ = Json::parse(&s); // must return, never panic
        }
    }

    #[test]
    fn raw_values_splice_verbatim_and_parse_back_to_the_source() {
        let entry = Json::obj(vec![
            ("level", Json::u64(2)),
            ("first", Json::u64(1)),
            ("last", Json::u64(16)),
        ]);
        let cached = entry.to_compact();
        let doc = Json::obj(vec![
            ("checkpoint", Json::u64(1)),
            (
                "tiers",
                Json::Arr(vec![Json::Raw(cached.clone()), Json::Raw(cached)]),
            ),
        ]);
        // The spliced output parses, and each spliced element parses
        // back to the document it was rendered from.
        let parsed = Json::parse(&doc.to_compact()).unwrap();
        let tiers = parsed.get("tiers").and_then(|t| t.as_arr()).unwrap();
        assert_eq!(tiers.len(), 2);
        assert_eq!(tiers[0], entry);
        // Pretty output keeps raw values on one line but stays valid.
        assert!(Json::parse(&doc.to_pretty()).is_ok());
        // Raw is opaque to the accessors.
        let raw = Json::Raw("{\"a\":1}".to_string());
        assert!(raw.get("a").is_none() && raw.as_u64().is_none());
    }

    #[test]
    fn non_finite_floats_serialize_as_null_in_release() {
        let r = std::panic::catch_unwind(|| Json::f64(f64::NAN).to_compact());
        if cfg!(debug_assertions) {
            assert!(r.is_err(), "debug builds must flag non-finite values");
        } else {
            assert_eq!(r.unwrap(), "null");
        }
    }
}
