//! Dense pid-indexed map.
//!
//! The simulated kernel allocates pids densely from 1, so per-pid probe
//! state (`cm_hash`, slot assignment, last waker, exit flags) is best
//! served by a plain vector indexed by pid: O(1) with no hashing at all,
//! the analogue of a `BPF_MAP_TYPE_ARRAY` keyed by pid. Iteration is in
//! ascending pid order, which makes downstream reports deterministic
//! without a sort.

/// Vector-backed map from `u32` pids to `T`.
#[derive(Clone, Debug, Default)]
pub struct PidMap<T> {
    slots: Vec<Option<T>>,
    len: usize,
    /// High-water mark of occupied entries (memory accounting).
    peak: usize,
}

impl<T> PidMap<T> {
    pub fn new() -> PidMap<T> {
        PidMap {
            slots: Vec::new(),
            len: 0,
            peak: 0,
        }
    }

    #[inline]
    pub fn get(&self, pid: u32) -> Option<&T> {
        self.slots.get(pid as usize).and_then(|s| s.as_ref())
    }

    #[inline]
    pub fn get_mut(&mut self, pid: u32) -> Option<&mut T> {
        self.slots.get_mut(pid as usize).and_then(|s| s.as_mut())
    }

    #[inline]
    pub fn contains(&self, pid: u32) -> bool {
        self.get(pid).is_some()
    }

    /// Insert, growing the backing vector as needed; returns the old
    /// value, if any.
    pub fn insert(&mut self, pid: u32, v: T) -> Option<T> {
        let i = pid as usize;
        if i >= self.slots.len() {
            self.slots.resize_with(i + 1, || None);
        }
        let old = self.slots[i].replace(v);
        if old.is_none() {
            self.len += 1;
            self.peak = self.peak.max(self.len);
        }
        old
    }

    pub fn remove(&mut self, pid: u32) -> Option<T> {
        let old = self.slots.get_mut(pid as usize).and_then(|s| s.take());
        if old.is_some() {
            self.len -= 1;
        }
        old
    }

    /// Mutable access to the entry, inserting `default()` if vacant
    /// (the `entry().or_insert_with()` idiom without hashing).
    pub fn get_mut_or(&mut self, pid: u32, default: impl FnOnce() -> T) -> &mut T {
        let i = pid as usize;
        if i >= self.slots.len() {
            self.slots.resize_with(i + 1, || None);
        }
        let slot = &mut self.slots[i];
        if slot.is_none() {
            *slot = Some(default());
            self.len += 1;
            self.peak = self.peak.max(self.len);
        }
        slot.as_mut().unwrap()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Occupied entries in ascending pid order.
    pub fn iter(&self) -> impl Iterator<Item = (u32, &T)> + '_ {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|v| (i as u32, v)))
    }

    /// Peak occupancy (for the paper's memory column).
    pub fn peak_len(&self) -> usize {
        self.peak
    }

    /// Approximate backing storage in bytes.
    pub fn approx_bytes(&self) -> u64 {
        (self.slots.capacity() * std::mem::size_of::<Option<T>>()) as u64
    }
}

impl PidMap<f64> {
    /// `map[pid] += delta`, inserting 0.0 first — BPF-style accumulate.
    #[inline]
    pub fn add(&mut self, pid: u32, delta: f64) {
        *self.get_mut_or(pid, || 0.0) += delta;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove() {
        let mut m: PidMap<u32> = PidMap::new();
        assert!(m.get(5).is_none());
        assert_eq!(m.insert(5, 50), None);
        assert_eq!(m.insert(5, 51), Some(50));
        assert_eq!(m.get(5), Some(&51));
        assert_eq!(m.len(), 1);
        assert_eq!(m.remove(5), Some(51));
        assert!(m.is_empty());
        assert_eq!(m.remove(5), None);
        assert_eq!(m.peak_len(), 1);
    }

    #[test]
    fn iter_is_pid_ordered() {
        let mut m: PidMap<&str> = PidMap::new();
        m.insert(9, "c");
        m.insert(1, "a");
        m.insert(4, "b");
        let got: Vec<(u32, &&str)> = m.iter().collect();
        assert_eq!(got, vec![(1, &"a"), (4, &"b"), (9, &"c")]);
    }

    #[test]
    fn accumulate_f64() {
        let mut m: PidMap<f64> = PidMap::new();
        m.add(3, 1.5);
        m.add(3, 2.5);
        assert_eq!(m.get(3), Some(&4.0));
    }

    #[test]
    fn get_mut_or_inserts_once() {
        let mut m: PidMap<Vec<u32>> = PidMap::new();
        m.get_mut_or(2, Vec::new).push(7);
        m.get_mut_or(2, Vec::new).push(8);
        assert_eq!(m.get(2), Some(&vec![7, 8]));
        assert_eq!(m.len(), 1);
    }
}
