//! Deterministic pseudo-random numbers: SplitMix64 seeding + xoshiro256**.
//!
//! Every stochastic choice in the simulator and the synthetic workloads
//! draws from a [`Prng`] seeded from the experiment seed, so runs are
//! bit-reproducible (the paper's "results are consistent across multiple
//! runs" claim is testable here by construction — and we also reproduce
//! the *Coz* baseline's run-to-run variance by giving it fresh seeds).

/// xoshiro256** PRNG with SplitMix64 seed expansion.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Derive an independent child stream (e.g. one per simulated thread).
    pub fn fork(&mut self, tag: u64) -> Prng {
        Prng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit value (xoshiro256**).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. `n` must be non-zero.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine for
        // simulation purposes; bias is < 2^-32 for n < 2^32.
        ((self.next_u64() >> 32).wrapping_mul(n)) >> 32
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exponentially distributed with the given mean.
    #[inline]
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Normal via Box–Muller (single value; the pair's twin is discarded —
    /// simplicity over throughput, this is not on the hot path).
    pub fn normal(&mut self, mean: f64, sd: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        mean + sd * (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Duration in ns: normal around `mean_ns` with `cv` coefficient of
    /// variation, clamped to `>= 1`.
    pub fn dur(&mut self, mean_ns: u64, cv: f64) -> u64 {
        let d = self.normal(mean_ns as f64, mean_ns as f64 * cv);
        d.max(1.0) as u64
    }

    /// True with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element index of a slice length.
    #[inline]
    pub fn pick(&mut self, len: usize) -> usize {
        self.below(len as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_in_range() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            assert!(p.below(10) < 10);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut p = Prng::new(9);
        for _ in 0..10_000 {
            let x = p.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_roughly_uniform() {
        let mut p = Prng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| p.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exp_mean_close() {
        let mut p = Prng::new(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| p.exp(5.0)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.15, "mean={mean}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Prng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same <= 1);
    }

    #[test]
    fn dur_positive() {
        let mut p = Prng::new(17);
        for _ in 0..1000 {
            assert!(p.dur(1000, 0.5) >= 1);
        }
    }
}
