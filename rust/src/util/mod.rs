//! Offline-build substrate: the crates this repo would normally pull from
//! crates.io (clap, criterion, proptest, rand) are unavailable in the
//! vendored offline registry, so the small pieces we need are implemented
//! here and tested like everything else.

pub mod prng;
pub mod stats;
pub mod cli;
pub mod bench;
pub mod check;
pub mod fxhash;
pub mod densemap;
pub mod json;

pub use densemap::PidMap;
pub use fxhash::{BuildFxHasher, FxHashMap, FxHashSet, FxHasher};
pub use prng::Prng;
pub use stats::Summary;

/// Saturating accumulate for hot-path `u64` counters (CMetric
/// femtoseconds, sketch weights): a wrap would silently demote the
/// heaviest entry in a ranking, so release builds clamp at `u64::MAX`
/// — the truthful direction — and debug builds assert.
#[inline]
pub fn sat_add(a: u64, b: u64) -> u64 {
    let s = a.checked_add(b);
    debug_assert!(s.is_some(), "u64 accumulator saturated ({a} + {b})");
    s.unwrap_or(u64::MAX)
}

#[cfg(test)]
mod sat_add_tests {
    #[test]
    fn saturates_in_release_asserts_in_debug() {
        assert_eq!(super::sat_add(u64::MAX - 5, 5), u64::MAX);
        let r = std::panic::catch_unwind(|| super::sat_add(u64::MAX, 1));
        if cfg!(debug_assertions) {
            assert!(r.is_err());
        } else {
            assert_eq!(r.unwrap(), u64::MAX);
        }
    }
}
