//! Offline-build substrate: the crates this repo would normally pull from
//! crates.io (clap, criterion, proptest, rand) are unavailable in the
//! vendored offline registry, so the small pieces we need are implemented
//! here and tested like everything else.

pub mod prng;
pub mod stats;
pub mod cli;
pub mod bench;
pub mod check;
pub mod fxhash;
pub mod densemap;

pub use densemap::PidMap;
pub use fxhash::{BuildFxHasher, FxHashMap, FxHashSet, FxHasher};
pub use prng::Prng;
pub use stats::Summary;
