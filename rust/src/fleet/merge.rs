//! The fleet merge core: re-intern N producers' stack-id namespaces
//! into one global map and fold their `shard_window` partials into one
//! cumulative merged session.
//!
//! Each producer numbers stacks with its own session-local ids; the
//! `symbols` event announces `id → frames (+ rendering)` once per id
//! (the id-stability contract). [`FleetMerge`] re-interns every
//! announced id through a global userspace [`StackMap`] keyed by the
//! raw frames — exactly the way the in-process session re-interns
//! recyclable kernel ids into the stable userspace map under `--lru` —
//! so two producers that captured the *same call path* merge into one
//! global path no matter what their local ids were. Producers that
//! never announce symbols (old captures) fall back to identity-by-raw-
//! id via a synthetic frame encoding, which reproduces the historical
//! `gapp aggregate` behaviour byte for byte.
//!
//! Everything folded here is associative (sums + `min(first_seen)`),
//! so the merged result is producer-count-invariant: splitting one
//! stream's lines across 1, 2, or N producers yields the same report
//! (property-tested in `tests/fleet_golden.rs`). Quarantine follows
//! the [`partials`] policy: count per producer, retain the first error
//! verbatim, never panic, never skip silently.

use crate::ebpf::StackMap;
use crate::gapp::sink::SymbolEntry;
use crate::gapp::stream::partials::{
    parse_envelope, parse_shard_window, parse_symbols, ProducerReport, ProducerStats,
};
use crate::gapp::stream::{TierPyramid, WindowSummary};
use crate::gapp::userspace::MergedPath;
use crate::util::FxHashMap;

/// Sentinel first frame of the synthetic stack that encodes "producer
/// never announced this id": the global identity of such a path is its
/// raw local id, `[SYNTHETIC_FRAME, local_id]`. `u64::MAX` is not a
/// reachable code address in any backend.
pub const SYNTHETIC_FRAME: u64 = u64::MAX;

/// One producer's namespace and accounting.
struct Producer {
    name: String,
    stats: ProducerStats,
    /// Windows that arrived after their fleet window had already been
    /// emitted (still merged into the cumulative total — the final
    /// report is lossless — but absent from the live merged stream).
    late: u64,
    /// Local stack id → global id.
    id_map: FxHashMap<u32, u32>,
    /// Local id → announced frames, for the id-stability contract: a
    /// re-announcement with different frames is a protocol violation.
    announced: FxHashMap<u32, Vec<u64>>,
}

/// What one ingested line meant, after validation and re-interning.
/// All stack ids in the payload are *global*.
pub enum Ingested {
    /// A `shard_window` partial: one producer's (window × shard)
    /// aggregation with ids re-interned and slices attributed to the
    /// producer (`app_slices` keyed by producer index).
    Window {
        index: u64,
        shard: u64,
        slices: u64,
        drained: u64,
        drops: u64,
        paths: Vec<MergedPath>,
    },
    /// A `symbols` announcement, re-interned: entries carry global ids.
    Symbols(Vec<SymbolEntry>),
    /// The producer's `session_start` (used to adopt its app names).
    Session { apps: Vec<String> },
    /// Any other valid v1 event kind — skipped by policy.
    Other,
}

/// Merges session streams from any number of producers into one
/// cumulative merged path set over a global stack-id namespace.
pub struct FleetMerge {
    stacks: StackMap,
    /// Global id → the producer-side rendering of its frames. On a
    /// cross-producer collision (same frames, different rendering) the
    /// lexicographically smallest rendering wins — deterministic in
    /// arrival order, so interleaved live ingest and sequential file
    /// ingest produce the same report.
    rendered: FxHashMap<u32, Vec<String>>,
    cumulative: FxHashMap<u32, MergedPath>,
    /// Tier compaction (see [`FleetMerge::compact`]): when set, folded
    /// windows land here instead of `cumulative`, which stays empty.
    tiers: Option<TierPyramid>,
    producers: Vec<Producer>,
}

impl Default for FleetMerge {
    fn default() -> FleetMerge {
        FleetMerge::new()
    }
}

impl FleetMerge {
    pub fn new() -> FleetMerge {
        FleetMerge {
            stacks: StackMap::new("fleet_stacks", 1 << 20),
            rendered: FxHashMap::default(),
            cumulative: FxHashMap::default(),
            tiers: None,
            producers: Vec::new(),
        }
    }

    /// Bound the cumulative fold for long-lived aggregation: each
    /// folded window becomes a tier-pyramid entry (base `base`), so the
    /// retained state is O(base · log T) entry path-sets over T windows
    /// instead of growing with every distinct path forever at full
    /// per-window granularity. Everything folded is associative, so the
    /// merged report is unchanged — [`FleetMerge::top`] re-folds the
    /// retained entries on demand. Call before the first fold.
    pub fn compact(&mut self, base: usize) {
        assert!(
            self.cumulative.is_empty(),
            "compact() must be enabled before the first fold"
        );
        self.tiers = Some(TierPyramid::new(base));
    }

    /// Retained tier entries (0 when compaction is off).
    pub fn tier_entries(&self) -> u64 {
        self.tiers.as_ref().map(|py| py.entries()).unwrap_or(0)
    }

    /// Register a producer slot; returns its index (= `app_slices` key
    /// in merged paths).
    pub fn register(&mut self, name: &str) -> usize {
        self.producers.push(Producer {
            name: name.to_string(),
            stats: ProducerStats::default(),
            late: 0,
            id_map: FxHashMap::default(),
            announced: FxHashMap::default(),
        });
        self.producers.len() - 1
    }

    /// Adopt a better display name for a slot (e.g. the app list from
    /// the producer's `session_start`).
    pub fn rename(&mut self, slot: usize, name: String) {
        if let Some(p) = self.producers.get_mut(slot) {
            p.name = name;
        }
    }

    /// Count one late window against a slot (reorder-horizon misses;
    /// see [`super::horizon`]).
    pub fn note_late(&mut self, slot: usize) {
        if let Some(p) = self.producers.get_mut(slot) {
            p.late += 1;
        }
    }

    /// Ingest one line from `slot`'s stream. Returns `None` when the
    /// line was quarantined (the slot's stats already account for it);
    /// the caller decides what to do with a validated [`Ingested`].
    pub fn ingest_line(&mut self, slot: usize, line: &str) -> Option<Ingested> {
        match self.classify_line(slot, line) {
            Ok(ing) => {
                let stats = &mut self.producers[slot].stats;
                stats.lines_ok += 1;
                if matches!(ing, Ingested::Window { .. }) {
                    stats.partials += 1;
                }
                Some(ing)
            }
            Err(e) => {
                let stats = &mut self.producers[slot].stats;
                stats.quarantined += 1;
                stats.first_error.get_or_insert(e);
                None
            }
        }
    }

    fn classify_line(&mut self, slot: usize, line: &str) -> Result<Ingested, String> {
        let env = parse_envelope(line)?;
        match env.event.as_str() {
            "symbols" => {
                // Validate the whole announcement (and the stability
                // contract) before interning any of it, so a line
                // corrupt in its third entry does not half-apply.
                let entries = parse_symbols(&env.value)?;
                for e in &entries {
                    if let Some(prev) = self.producers[slot].announced.get(&e.stack_id) {
                        if prev != &e.frames {
                            return Err(format!(
                                "stack id {} re-announced with different frames \
                                 (id-stability contract violation)",
                                e.stack_id
                            ));
                        }
                    }
                }
                let mut global = Vec::with_capacity(entries.len());
                for e in entries {
                    let gid = self.stacks.intern(&e.frames);
                    let p = &mut self.producers[slot];
                    p.id_map.insert(e.stack_id, gid);
                    p.announced.insert(e.stack_id, e.frames.clone());
                    if !e.rendered.is_empty() {
                        match self.rendered.get(&gid) {
                            Some(prev) if *prev <= e.rendered => {}
                            _ => {
                                self.rendered.insert(gid, e.rendered.clone());
                            }
                        }
                    }
                    global.push(SymbolEntry {
                        stack_id: gid,
                        frames: e.frames,
                        rendered: e.rendered,
                    });
                }
                Ok(Ingested::Symbols(global))
            }
            "shard_window" => {
                let wire = parse_shard_window(&env.value)?;
                let mut paths = Vec::with_capacity(wire.paths.len());
                for wp in wire.paths {
                    let gid = match self.producers[slot].id_map.get(&wp.stack_id) {
                        Some(gid) => *gid,
                        // Unannounced id (a pre-symbols capture, or a
                        // stream whose symbols line was quarantined):
                        // identity is the raw id, so equal raw ids
                        // across producers merge — the historical
                        // `gapp aggregate` behaviour.
                        None => {
                            let gid = self
                                .stacks
                                .intern(&[SYNTHETIC_FRAME, wp.stack_id as u64]);
                            self.producers[slot].id_map.insert(wp.stack_id, gid);
                            gid
                        }
                    };
                    let mut p = MergedPath::new(gid);
                    p.cm_fs = wp.cm_fs;
                    p.total_cm_ns = p.cm_fs as f64 / 1e6;
                    p.slices = wp.slices;
                    p.first_seen = wp.first_seen;
                    // Per-producer attribution rides the same field
                    // per-app attribution uses in-process; any per-app
                    // split the producer shipped is its own, local
                    // story — the fleet re-keys by producer.
                    p.app_slices.insert(slot as u16, wp.slices);
                    paths.push(p);
                }
                Ok(Ingested::Window {
                    index: wire.index,
                    shard: wire.shard,
                    slices: wire.slices,
                    drained: wire.drained,
                    drops: wire.drops,
                    paths,
                })
            }
            "session_start" => {
                let apps = env
                    .value
                    .get("session")
                    .and_then(|s| s.get("apps"))
                    .and_then(|a| a.as_arr())
                    .map(|arr| {
                        arr.iter()
                            .filter_map(|s| s.as_str().map(|s| s.to_string()))
                            .collect::<Vec<String>>()
                    })
                    .unwrap_or_default();
                Ok(Ingested::Session { apps })
            }
            _ => Ok(Ingested::Other),
        }
    }

    /// Fold merged-window paths (global ids) into the cumulative set —
    /// or, under [`FleetMerge::compact`], into the tier pyramid as one
    /// window. The pyramid numbers windows by fold order (fleet window
    /// indices can arrive late and out of order; the fold is
    /// associative and commutative across whole windows, so fold order
    /// is immaterial to the merged result).
    pub fn fold(&mut self, paths: &[MergedPath]) {
        match self.tiers.as_mut() {
            Some(py) => {
                let summary = WindowSummary {
                    index: py.windows_total() + 1,
                    slices: paths.iter().map(|p| p.slices).sum(),
                    drained: 0,
                    drops: 0,
                };
                let _ = py.push(summary, paths.to_vec());
            }
            None => {
                for p in paths {
                    self.cumulative
                        .entry(p.stack_id)
                        .or_insert_with(|| MergedPath::new(p.stack_id))
                        .merge_from(p);
                }
            }
        }
    }

    /// The cumulative merged paths, one per distinct global id
    /// (re-folded from the retained tier entries under compaction).
    fn merged_cumulative(&self) -> Vec<MergedPath> {
        match &self.tiers {
            Some(py) => py.merged_cumulative(),
            None => self.cumulative.values().cloned().collect(),
        }
    }

    /// One-shot ingestion of a whole captured stream (the `gapp
    /// aggregate` path): every validated window folds immediately —
    /// offline replay has no reorder problem. Never fails; malformed
    /// lines are quarantined into the producer's stats.
    pub fn ingest(&mut self, producer: &str, text: &str) {
        let slot = self.register(producer);
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            if let Some(Ingested::Window { paths, .. }) = self.ingest_line(slot, line) {
                self.fold(&paths);
            }
        }
    }

    /// Ingest a JSONL file, using its path as the producer name. I/O
    /// failure is a real error; content failures quarantine per line.
    pub fn ingest_file(&mut self, path: &str) -> anyhow::Result<()> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read partials {path:?}: {e}"))?;
        self.ingest(path, &text);
        Ok(())
    }

    /// The frames behind a global id (symbol round-trip surface).
    pub fn resolve(&self, gid: u32) -> &[u64] {
        self.stacks.resolve(gid)
    }

    /// The producer-side rendering of a global id's frames, if any
    /// producer announced one.
    pub fn rendering(&self, gid: u32) -> Option<&[String]> {
        self.rendered.get(&gid).map(|r| r.as_slice())
    }

    /// Registered producer slots.
    pub fn producer_count(&self) -> usize {
        self.producers.len()
    }

    /// Per-producer accounting, in registration order.
    pub fn producers(&self) -> Vec<ProducerReport> {
        self.producers
            .iter()
            .map(|p| ProducerReport {
                name: p.name.clone(),
                stats: p.stats.clone(),
            })
            .collect()
    }

    /// Total quarantined lines across all producers.
    pub fn quarantined(&self) -> u64 {
        self.producers.iter().map(|p| p.stats.quarantined).sum()
    }

    /// Number of distinct merged paths (global ids). Under compaction
    /// this re-folds the retained entries — display-path cost only.
    pub fn len(&self) -> usize {
        match &self.tiers {
            Some(py) => py.merged_cumulative().len(),
            None => self.cumulative.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        match &self.tiers {
            Some(py) => py.retained_paths() == 0,
            None => self.cumulative.is_empty(),
        }
    }

    /// Merged paths ranked by CMetric (ties: earlier first-seen, then
    /// the lexicographically smallest *frames* — the identity that is
    /// invariant to how the streams were split across producers; global
    /// ids depend on arrival order and must not leak into the order).
    pub fn top(&self, n: usize) -> Vec<MergedPath> {
        let mut all = self.merged_cumulative();
        all.sort_by(|a, b| {
            b.cm_fs
                .cmp(&a.cm_fs)
                .then(a.first_seen.cmp(&b.first_seen))
                .then_with(|| {
                    self.stacks
                        .resolve(a.stack_id)
                        .cmp(self.stacks.resolve(b.stack_id))
                })
        });
        all.truncate(n);
        all
    }

    /// The display label for one merged path: the innermost rendered
    /// frame when a producer announced symbols, the historical
    /// `stack <id>` form for raw-id fallback paths. Derived only from
    /// producer-provided data, never from the global id, so the label
    /// is split-invariant.
    pub fn site(&self, gid: u32) -> String {
        if let Some(r) = self.rendered.get(&gid) {
            if let Some(first) = r.first() {
                return first.clone();
            }
        }
        let frames = self.stacks.resolve(gid);
        match frames {
            [SYNTHETIC_FRAME, raw] => format!("stack {raw:>6}"),
            [] => "??".to_string(),
            _ => format!("0x{:x}", frames[0]),
        }
    }

    /// Render the fleet-aggregation report: per-producer accounting
    /// (quarantine and lateness are *visible*, never silent) followed
    /// by the merged top-N ([`FleetMerge::render_top`]).
    pub fn render(&self, n: usize) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        writeln!(
            out,
            "fleet partials: {} producer(s), {} merged path(s)",
            self.producers.len(),
            self.len(),
        )
        .unwrap();
        for p in &self.producers {
            write!(
                out,
                "  {}: {} line(s) ok, {} partial(s), {} quarantined",
                p.name, p.stats.lines_ok, p.stats.partials, p.stats.quarantined,
            )
            .unwrap();
            if p.late > 0 {
                write!(out, ", {} late window(s)", p.late).unwrap();
            }
            match &p.stats.first_error {
                Some(e) => writeln!(out, " (first error: {e})").unwrap(),
                None => writeln!(out).unwrap(),
            }
        }
        out.push_str(&self.render_top(n));
        out
    }

    /// The merged top-N section alone — every byte derives from
    /// producer-provided data, so this section is identical no matter
    /// how the same windows were split across producers (the accounting
    /// lines above it legitimately vary with the split). This is the
    /// surface the golden/property tests and the CI fleet smoke diff.
    pub fn render_top(&self, n: usize) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let top = self.top(n);
        if top.is_empty() {
            writeln!(out, "no partials merged").unwrap();
        } else {
            writeln!(out, "top {} path(s) by CMetric:", top.len()).unwrap();
            for p in &top {
                writeln!(
                    out,
                    "  {}  cm {:>10.3} ms  slices {:>6}  first seen {}",
                    self.site(p.stack_id),
                    p.cm_fs as f64 / 1e12,
                    p.slices,
                    p.first_seen,
                )
                .unwrap();
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gapp::sink::json::SCHEMA_VERSION;
    use crate::util::json::Json;

    pub(crate) fn window_line(index: u64, shard: u64, paths: &[(u64, u64, u64, u64)]) -> String {
        Json::obj(vec![
            ("schema", Json::u64(SCHEMA_VERSION)),
            ("event", Json::str("shard_window")),
            (
                "shard_window",
                Json::obj(vec![
                    ("index", Json::u64(index)),
                    ("shard", Json::u64(shard)),
                    ("slices", Json::u64(paths.iter().map(|p| p.2).sum())),
                    ("drained", Json::u64(paths.iter().map(|p| p.2).sum())),
                    ("drops", Json::u64(0)),
                    (
                        "paths",
                        Json::Arr(
                            paths
                                .iter()
                                .map(|(id, cm, sl, fs)| {
                                    Json::obj(vec![
                                        ("stack_id", Json::u64(*id)),
                                        ("cm_fs", Json::u64(*cm)),
                                        ("slices", Json::u64(*sl)),
                                        ("first_seen", Json::u64(*fs)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ),
        ])
        .to_compact()
    }

    pub(crate) fn symbols_line(entries: &[(u64, &[u64], &[&str])]) -> String {
        Json::obj(vec![
            ("schema", Json::u64(SCHEMA_VERSION)),
            ("event", Json::str("symbols")),
            (
                "symbols",
                Json::obj(vec![(
                    "entries",
                    Json::Arr(
                        entries
                            .iter()
                            .map(|(id, frames, rendered)| {
                                Json::obj(vec![
                                    ("stack_id", Json::u64(*id)),
                                    (
                                        "frames",
                                        Json::Arr(
                                            frames.iter().map(|a| Json::u64(*a)).collect(),
                                        ),
                                    ),
                                    (
                                        "rendered",
                                        Json::Arr(rendered.iter().map(Json::str).collect()),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                )]),
            ),
        ])
        .to_compact()
    }

    #[test]
    fn same_frames_from_different_local_ids_merge_into_one_global_path() {
        // Producer A calls the path "id 7"; producer B calls the same
        // frames "id 3". Symbol exchange must unify them.
        let a = format!(
            "{}\n{}\n",
            symbols_line(&[(7, &[0x40, 0x90], &["emd (emd.c:57)", "main"])]),
            window_line(1, 0, &[(7, 100, 2, 40)]),
        );
        let b = format!(
            "{}\n{}\n",
            symbols_line(&[(3, &[0x40, 0x90], &["emd (emd.c:57)", "main"])]),
            window_line(1, 0, &[(3, 50, 1, 12)]),
        );
        let mut fleet = FleetMerge::new();
        fleet.ingest("nodeA", &a);
        fleet.ingest("nodeB", &b);
        assert_eq!(fleet.quarantined(), 0);
        assert_eq!(fleet.len(), 1, "one global path");
        let top = fleet.top(10);
        assert_eq!(top[0].cm_fs, 150);
        assert_eq!(top[0].slices, 3);
        assert_eq!(top[0].first_seen, 12);
        // Symbol round-trip: the merged id resolves to the original
        // producer frames.
        assert_eq!(fleet.resolve(top[0].stack_id), &[0x40, 0x90]);
        assert_eq!(fleet.site(top[0].stack_id), "emd (emd.c:57)");
        // Per-producer attribution: both producers contributed.
        assert_eq!(top[0].app_slices.get(&0), Some(&2));
        assert_eq!(top[0].app_slices.get(&1), Some(&1));
        assert!(fleet.render(5).contains("emd (emd.c:57)"));
    }

    #[test]
    fn unannounced_ids_fall_back_to_raw_id_identity() {
        // No symbols events at all (an old capture): equal raw ids
        // merge across producers, and the report renders the raw id.
        let a = window_line(1, 0, &[(7, 100, 2, 40)]);
        let b = window_line(1, 1, &[(7, 30, 1, 90)]);
        let mut fleet = FleetMerge::new();
        fleet.ingest("nodeA", &a);
        fleet.ingest("nodeB", &b);
        assert_eq!(fleet.len(), 1);
        let top = fleet.top(10);
        assert_eq!(top[0].cm_fs, 130);
        let r = fleet.render(5);
        assert!(r.contains("stack      7"), "{r}");
    }

    #[test]
    fn id_stability_violations_are_quarantined() {
        // Same local id announced twice with different frames: the
        // second announcement is a protocol violation — quarantined,
        // and the first meaning stays in force.
        let text = format!(
            "{}\n{}\n{}\n",
            symbols_line(&[(7, &[0x40], &["f"])]),
            symbols_line(&[(7, &[0x41], &["g"])]),
            window_line(1, 0, &[(7, 100, 1, 5)]),
        );
        let mut fleet = FleetMerge::new();
        fleet.ingest("p", &text);
        let reports = fleet.producers();
        assert_eq!(reports[0].stats.quarantined, 1);
        let err = reports[0].stats.first_error.clone().unwrap();
        assert!(err.contains("id-stability"), "{err}");
        assert_eq!(fleet.resolve(fleet.top(1)[0].stack_id), &[0x40]);
        // Re-announcing the *same* frames (a resume replay) is a no-op.
        let text = format!("{0}\n{0}\n", symbols_line(&[(9, &[0x50], &["h"])]));
        let mut fleet = FleetMerge::new();
        fleet.ingest("p", &text);
        assert_eq!(fleet.quarantined(), 0);
    }

    #[test]
    fn compacted_fleet_fold_renders_identically_with_bounded_entries() {
        // Many single-path windows across two producers: the compacted
        // merge must render the same top section as the flat map while
        // retaining only O(base · log T) tier entries.
        let mut streams = Vec::new();
        for producer in 0..2u64 {
            let mut text = format!(
                "{}\n",
                symbols_line(&[
                    (1, &[0x40, 0x90], &["emd (emd.c:57)", "main"]),
                    (2, &[0x50, 0x90], &["fluid (f.c:9)", "main"]),
                ])
            );
            for w in 1..=40u64 {
                let id = 1 + (w + producer) % 2;
                text.push_str(&window_line(
                    w,
                    0,
                    &[(id, 100 + w * 7, 1 + w % 3, 10 * w + producer)],
                ));
                text.push('\n');
            }
            streams.push(text);
        }
        let mut flat = FleetMerge::new();
        let mut compacted = FleetMerge::new();
        compacted.compact(2);
        for (i, s) in streams.iter().enumerate() {
            flat.ingest(&format!("node{i}"), s);
            compacted.ingest(&format!("node{i}"), s);
        }
        assert_eq!(flat.quarantined(), 0);
        assert_eq!(compacted.len(), flat.len());
        assert_eq!(compacted.render_top(10), flat.render_top(10));
        // 80 folded windows in base 2: digit-sum-of-80 entries ≤ 7.
        let entries = compacted.tier_entries();
        assert!(
            (1..=7).contains(&entries),
            "expected O(log T) entries, got {entries}"
        );
        assert_eq!(flat.tier_entries(), 0);
    }

    #[test]
    fn rendering_collisions_resolve_deterministically() {
        // Two producers announce the same frames with different
        // renderings; the lexicographically smaller must win no matter
        // the ingestion order.
        let sym_a = symbols_line(&[(1, &[0x40], &["beta"])]);
        let sym_b = symbols_line(&[(2, &[0x40], &["alpha"])]);
        let win_a = window_line(1, 0, &[(1, 10, 1, 3)]);
        let win_b = window_line(1, 0, &[(2, 10, 1, 4)]);
        for order in [[0usize, 1], [1, 0]] {
            let mut fleet = FleetMerge::new();
            let streams = [
                format!("{sym_a}\n{win_a}\n"),
                format!("{sym_b}\n{win_b}\n"),
            ];
            for i in order {
                fleet.ingest(&format!("p{i}"), &streams[i]);
            }
            let top = fleet.top(1);
            assert_eq!(fleet.site(top[0].stack_id), "alpha");
        }
    }
}
