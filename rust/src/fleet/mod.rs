//! Fleet aggregation: merge live session streams from N producer
//! processes into one merged session (GAPP's "profile the fleet, not
//! the host" layer — ROADMAP north star, open item 1).
//!
//! The subsystem crosses the last serialization boundary the profiler
//! has: the process. Producers ship their opt-in `shard_window`
//! partials — plus the additive `symbols` id → frames exchange — as
//! flush-per-event JSONL over a pipe or Unix socket ([`StreamSink`],
//! `gapp live --stream PATH`). The service (`gapp serve --listen
//! PATH`, [`service::serve`]) re-interns every producer's session-local
//! stack ids through one global map ([`FleetMerge`]), aligns windows
//! across producers under a bounded reorder horizon
//! ([`ReorderHorizon`]), folds the partials through the in-process
//! [`crate::gapp::stream::merge_tree`] at fleet-window close, and
//! re-emits one merged schema-1 session through the ordinary sink API
//! — so `gapp aggregate` (offline, [`FleetMerge::ingest_file`]) is the
//! one-shot special case and merged streams aggregate hierarchically.
//!
//! Correctness leans on the same two theorems as every earlier merge
//! layer: all folded quantities are associative (sums,
//! `min(first_seen)`) and path identity is producer-invariant (the
//! announced frames, or the raw id for pre-symbols captures), so the
//! merged report is byte-identical no matter how the same windows were
//! split across 1, 2, or N producers — property-tested in
//! `tests/fleet_golden.rs` and smoke-tested end-to-end in CI.

pub mod horizon;
pub mod merge;
pub mod service;
pub mod stream;

pub use horizon::{Offer, ReorderHorizon, WindowPart};
pub use merge::{FleetMerge, Ingested};
pub use service::{serve, serve_on, ServeConfig};
pub use stream::StreamSink;
