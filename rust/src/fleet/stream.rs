//! Producer-side transport: frame the session's event stream as JSONL
//! over a pipe or Unix socket, one flushed line per event.
//!
//! [`StreamSink`] is an ordinary [`ReportSink`], attached through the
//! session builder like any other (`Session::sink(..)` tees
//! internally), so a producer streams to a fleet aggregator with no
//! driver changes: the CLI resolves `--stream PATH` to this sink and
//! everything else is untouched. The JSONL framing is byte-identical
//! to `--format jsonl --output FILE` — the aggregator cannot tell a
//! live socket from a replayed capture — except that every event is
//! flushed as it is emitted ([`JsonlSink::streaming`]), because a
//! buffered tail on a live transport would hold the newest windows
//! back indefinitely.

use std::fs::{File, OpenOptions};
use std::io;
use std::os::unix::fs::FileTypeExt;
use std::os::unix::net::UnixStream;

use anyhow::{anyhow, Context, Result};

use crate::gapp::sink::{JsonlSink, ReportEvent, ReportSink};

/// The connected byte stream under the JSONL framing. A Unix socket
/// when the path names one (the `gapp serve` transport), otherwise an
/// appended file — which covers FIFOs (`mkfifo`) and plain capture
/// files with the same open call.
pub enum StreamConn {
    Unix(UnixStream),
    File(File),
}

impl io::Write for StreamConn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            StreamConn::Unix(s) => s.write(buf),
            StreamConn::File(f) => f.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            StreamConn::Unix(s) => s.flush(),
            StreamConn::File(f) => f.flush(),
        }
    }
}

/// A [`ReportSink`] that ships the session's events to a fleet
/// aggregator as flush-per-event JSONL.
pub struct StreamSink {
    inner: JsonlSink<StreamConn>,
}

impl StreamSink {
    /// Connect to a stream target. An existing Unix socket connects as
    /// a socket; anything else (a FIFO, a plain file, a not-yet-created
    /// path) opens in append mode so several producers can share one
    /// FIFO without clobbering each other.
    pub fn connect(path: &str) -> Result<StreamSink> {
        if path.is_empty() {
            return Err(anyhow!("--stream needs a non-empty path"));
        }
        let conn = match std::fs::metadata(path) {
            Ok(md) if md.file_type().is_socket() => StreamConn::Unix(
                UnixStream::connect(path)
                    .with_context(|| format!("cannot connect stream socket {path:?}"))?,
            ),
            _ => StreamConn::File(
                OpenOptions::new()
                    .append(true)
                    .create(true)
                    .open(path)
                    .with_context(|| format!("cannot open stream target {path:?}"))?,
            ),
        };
        Ok(StreamSink {
            inner: JsonlSink::streaming(conn),
        })
    }
}

impl ReportSink for StreamSink {
    fn on_event(&mut self, ev: &ReportEvent<'_>) -> Result<()> {
        self.inner.on_event(ev)
    }

    fn finish(&mut self) -> Result<()> {
        self.inner.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};
    use std::os::unix::net::UnixListener;

    #[test]
    fn stream_sink_appends_jsonl_to_a_file() {
        let path = std::env::temp_dir().join("gapp_stream_sink_file.jsonl");
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        {
            let mut sink = StreamSink::connect(&path).unwrap();
            sink.on_event(&ReportEvent::SessionEnd { runtime_ns: 7 }).unwrap();
            sink.finish().unwrap();
        }
        {
            // A second producer appends, never truncates.
            let mut sink = StreamSink::connect(&path).unwrap();
            sink.on_event(&ReportEvent::SessionEnd { runtime_ns: 8 }).unwrap();
            sink.finish().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"session_end\""));
        assert!(lines[1].contains("\"runtime_ns\":8"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn stream_sink_connects_to_a_unix_socket_and_each_event_is_readable_immediately() {
        let path = std::env::temp_dir().join("gapp_stream_sink.sock");
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path).unwrap();
        let mut sink = StreamSink::connect(&path).unwrap();
        let (conn, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(conn);

        // The regression this guards: an event must be on the wire as
        // soon as on_event returns — before finish(), before the
        // session ends. read_line would block forever on a buffered
        // writer that held the line back.
        sink.on_event(&ReportEvent::SessionEnd { runtime_ns: 42 }).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"event\":\"session_end\""), "{line}");
        assert!(line.contains("\"runtime_ns\":42"), "{line}");

        sink.finish().unwrap();
        drop(sink);
        let _ = std::fs::remove_file(&path);
    }
}
