//! Bounded reorder horizon: align producers' windows for the merged
//! live stream without losing anything from the final report.
//!
//! Producers emit windows in order *within* their own streams, but the
//! fleet sees the streams interleaved arbitrarily — one producer may
//! be minutes ahead of another. A fleet window (keyed by the
//! producer-local window index; sessions sharing a `--window-us` tick
//! the same clock) closes when every active producer has moved past it
//! or finished, OR when the fastest producer has run `horizon` windows
//! ahead — the bound that keeps buffering O(horizon), not O(lag).
//!
//! A window that arrives after its fleet window closed is **Late**:
//! excluded from the already-emitted merged stream but NOT dropped —
//! the caller folds it straight into the cumulative total (associative
//! merges don't care when) and accounts it per producer. That is what
//! keeps the final fleet report lossless — byte-identical to a one-shot
//! `gapp aggregate` over the same captures — even when producers run
//! one after another instead of concurrently.

use std::collections::BTreeMap;

use crate::gapp::userspace::MergedPath;

/// One producer's partial of one fleet window, buffered until the
/// window closes.
pub struct WindowPart {
    pub producer: usize,
    pub slices: u64,
    pub drained: u64,
    pub drops: u64,
    pub paths: Vec<MergedPath>,
}

/// A closed fleet window: every buffered part, plus the summed
/// accounting for the merged `shard_window` re-emission.
pub struct ClosedWindow {
    pub index: u64,
    pub slices: u64,
    pub drained: u64,
    pub drops: u64,
    pub parts: Vec<Vec<MergedPath>>,
}

/// The verdict on one offered window part.
pub enum Offer {
    /// Buffered; will appear in the merged stream at window close.
    Accepted,
    /// Its fleet window already closed: the part comes back to the
    /// caller, who folds it into the cumulative total directly and
    /// accounts it as late.
    Late(WindowPart),
}

struct Cursor {
    /// Highest window index seen from this producer (0 = none yet).
    /// A producer still emits parts for its watermark window (one per
    /// shard), so a window only closes once every watermark is *past*.
    watermark: u64,
    eof: bool,
}

pub struct ReorderHorizon {
    horizon: u64,
    /// Highest window index already closed and handed out.
    emitted_through: u64,
    pending: BTreeMap<u64, Vec<WindowPart>>,
    producers: Vec<Cursor>,
}

impl ReorderHorizon {
    /// `horizon` = how many windows the fastest producer may run ahead
    /// before stragglers are declared late (≥ 1).
    pub fn new(horizon: u64) -> ReorderHorizon {
        ReorderHorizon {
            horizon: horizon.max(1),
            emitted_through: 0,
            pending: BTreeMap::new(),
            producers: Vec::new(),
        }
    }

    /// Register one producer slot; returns its index. Must match the
    /// slot numbering of the merge core.
    pub fn register(&mut self) -> usize {
        self.producers.push(Cursor {
            watermark: 0,
            eof: false,
        });
        self.producers.len() - 1
    }

    /// Ensure slots `0..=slot` exist (lazy registration from a message
    /// loop that discovers producers by their first line).
    pub fn ensure(&mut self, slot: usize) {
        while self.producers.len() <= slot {
            self.register();
        }
    }

    /// Offer one producer's (window × shard) part.
    pub fn offer(&mut self, part: WindowPart, index: u64) -> Offer {
        self.ensure(part.producer);
        let c = &mut self.producers[part.producer];
        c.watermark = c.watermark.max(index);
        if index <= self.emitted_through {
            return Offer::Late(part);
        }
        self.pending.entry(index).or_default().push(part);
        Offer::Accepted
    }

    /// Mark one producer finished (its stream hit EOF): it no longer
    /// holds any window open.
    pub fn eof(&mut self, slot: usize) {
        self.ensure(slot);
        self.producers[slot].eof = true;
    }

    /// Pop every fleet window that can close, in index order. Call
    /// after each offer/eof.
    pub fn ready(&mut self) -> Vec<ClosedWindow> {
        let mut out = Vec::new();
        loop {
            let highest = self
                .pending
                .keys()
                .next_back()
                .copied()
                .unwrap_or(0)
                .max(self.producers.iter().map(|c| c.watermark).max().unwrap_or(0));
            let w = self.emitted_through + 1;
            if w > highest {
                break;
            }
            let all_past = self
                .producers
                .iter()
                .all(|c| c.eof || c.watermark > w);
            let forced = self
                .producers
                .iter()
                .any(|c| c.watermark.saturating_sub(w) >= self.horizon);
            if !(all_past || forced) {
                break;
            }
            self.emitted_through = w;
            let parts = self.pending.remove(&w).unwrap_or_default();
            if parts.is_empty() {
                // A gap (every part of this index quarantined, or the
                // producers skipped it): nothing to emit, keep walking.
                continue;
            }
            let mut closed = ClosedWindow {
                index: w,
                slices: 0,
                drained: 0,
                drops: 0,
                parts: Vec::with_capacity(parts.len()),
            };
            for p in parts {
                closed.slices += p.slices;
                closed.drained += p.drained;
                closed.drops += p.drops;
                closed.parts.push(p.paths);
            }
            out.push(closed);
        }
        out
    }

    /// Windows still buffered (diagnostics / tests).
    pub fn pending_windows(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn part(producer: usize, slices: u64) -> WindowPart {
        WindowPart {
            producer,
            slices,
            drained: slices,
            drops: 0,
            paths: Vec::new(),
        }
    }

    #[test]
    fn windows_close_in_order_once_every_producer_is_past() {
        let mut h = ReorderHorizon::new(8);
        h.register();
        h.register();
        assert!(matches!(h.offer(part(0, 1), 1), Offer::Accepted));
        // Producer 1 hasn't reached window 1 yet: nothing closes.
        assert!(h.ready().is_empty());
        assert!(matches!(h.offer(part(1, 2), 1), Offer::Accepted));
        // Both producers are AT window 1 (more shards may come).
        assert!(h.ready().is_empty());
        // Both move to window 2: window 1 closes with both parts.
        h.offer(part(0, 1), 2);
        h.offer(part(1, 1), 2);
        let closed = h.ready();
        assert_eq!(closed.len(), 1);
        assert_eq!(closed[0].index, 1);
        assert_eq!(closed[0].slices, 3);
        assert_eq!(closed[0].parts.len(), 2);
    }

    #[test]
    fn eof_releases_everything_a_producer_held_open() {
        let mut h = ReorderHorizon::new(8);
        h.register();
        h.register();
        h.offer(part(0, 1), 1);
        h.offer(part(0, 1), 2);
        assert!(h.ready().is_empty(), "producer 1 still holds window 1");
        h.eof(1);
        // Producer 0 still holds its own watermark window (2) open.
        let closed = h.ready();
        assert_eq!(closed.len(), 1);
        assert_eq!(closed[0].index, 1);
        h.eof(0);
        let closed = h.ready();
        assert_eq!(closed.len(), 1);
        assert_eq!(closed[0].index, 2);
        assert_eq!(h.pending_windows(), 0);
    }

    #[test]
    fn a_straggler_is_forced_out_at_the_horizon_and_late_parts_are_flagged() {
        let mut h = ReorderHorizon::new(3);
        h.register();
        h.register();
        h.offer(part(0, 1), 1);
        // Producer 0 sprints ahead; window 1 must close when the lead
        // reaches the horizon even though producer 1 never showed up.
        h.offer(part(0, 1), 2);
        h.offer(part(0, 1), 3);
        assert!(h.ready().is_empty());
        h.offer(part(0, 1), 4);
        let closed = h.ready();
        assert_eq!(closed.len(), 1);
        assert_eq!(closed[0].index, 1);
        // The straggler's window 1 part now arrives: late, not lost —
        // it comes back for the caller to fold into the cumulative
        // total.
        match h.offer(part(1, 9), 1) {
            Offer::Late(p) => assert_eq!(p.slices, 9),
            Offer::Accepted => panic!("window 1 already closed"),
        }
        // But its window 2 part is still in time.
        assert!(matches!(h.offer(part(1, 1), 2), Offer::Accepted));
    }

    #[test]
    fn sequential_producers_lose_nothing() {
        // The CI shape: producer 0 runs to completion, then producer 1
        // starts. With EOF semantics nothing is late.
        let mut h = ReorderHorizon::new(4);
        let mut emitted = 0u64;
        let mut late = 0u64;
        let mut feed = |h: &mut ReorderHorizon, slot: usize| {
            for w in 1..=10u64 {
                if let Offer::Late(_) = h.offer(part(slot, 1), w) {
                    // The service folds late parts into the cumulative
                    // total directly — counted, never lost.
                    late += 1;
                }
                emitted += h.ready().iter().map(|c| c.slices).sum::<u64>();
            }
            h.eof(slot);
            emitted += h.ready().iter().map(|c| c.slices).sum::<u64>();
        };
        h.register();
        feed(&mut h, 0);
        // A second producer connects only after the first finished: its
        // windows are all late (the merged stream moved on) but every
        // one of them still reaches the cumulative total.
        assert_eq!(h.register(), 1);
        feed(&mut h, 1);
        assert_eq!(emitted + late, 20, "every part accounted for");
        assert!(late > 0, "the sequential producer must be late");
        assert_eq!(h.pending_windows(), 0);
    }
}
