//! The fleet aggregation service: `gapp serve --listen PATH`.
//!
//! A long-lived ingest loop in the PR 8 lane shape — one blocking
//! reader thread per accepted connection, each feeding lines into one
//! shared channel; a single merge driver on the caller's thread owns
//! the [`FleetMerge`] core, the [`ReorderHorizon`] and the output
//! sinks. Producers connect with `gapp live --stream PATH`, the driver
//! re-interns their id namespaces and folds their `shard_window`
//! partials through the existing [`merge_tree`] at fleet-window close,
//! and the result is re-emitted as **one merged session** through the
//! ordinary sink API: a `symbols` announcement per window of fresh
//! global ids, then one merged `shard_window` whose paths carry
//! per-producer attribution (`app_slices` keyed by accept-order slot,
//! serialized as the additive `"apps"` field). The merged stream is
//! itself a valid schema-1 capture — feeding it back through `gapp
//! aggregate` reproduces the same report (hierarchical aggregation).
//!
//! Robustness follows the reader-half contract: malformed lines are
//! quarantined per producer (count + first error, never a panic),
//! stragglers past the reorder horizon are folded into the cumulative
//! total and accounted late — the *final* report stays lossless, and
//! `gapp aggregate` is exactly the one-shot special case of this loop.

use std::io::{BufRead, BufReader};
use std::os::unix::fs::FileTypeExt;
use std::os::unix::net::UnixListener;
use std::path::Path;
use std::sync::mpsc::{channel, Sender};

use anyhow::{anyhow, Context, Result};

use crate::gapp::config::GappConfig;
use crate::gapp::sink::{
    ReportEvent, ReportSink, SessionInfo, SessionMode, ShardWindowEvent, SymbolEntry,
    SymbolsEvent,
};
use crate::gapp::stream::merge_tree;
use crate::util::FxHashSet;

use super::horizon::{ClosedWindow, Offer, ReorderHorizon, WindowPart};
use super::merge::{FleetMerge, Ingested};

/// Resolved `gapp serve` configuration.
pub struct ServeConfig {
    /// Unix socket path to listen on.
    pub listen: String,
    /// Number of producer connections to serve before finishing (the
    /// v1 service is bounded: it exits, renders and returns once every
    /// expected producer has disconnected).
    pub producers: usize,
    /// Top-N paths in the final fleet report.
    pub top: usize,
    /// Reorder horizon, in windows (see [`ReorderHorizon`]).
    pub horizon: u64,
    /// Tier-compaction base for the cumulative fold (`None` = flat map;
    /// see [`FleetMerge::compact`]).
    pub compact_base: Option<usize>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            listen: String::new(),
            producers: 1,
            top: 10,
            horizon: 8,
            compact_base: None,
        }
    }
}

enum Msg {
    Line { slot: usize, text: String },
    Eof { slot: usize },
}

/// Validate and bind the listen address. A stale *socket* left by a
/// previous serve is replaced; anything else at the path is refused —
/// never silently clobber an operator's file.
fn bind(listen: &str) -> Result<UnixListener> {
    if listen.is_empty() {
        return Err(anyhow!("--listen needs a non-empty socket path"));
    }
    let p = Path::new(listen);
    if let Some(dir) = p.parent() {
        if !dir.as_os_str().is_empty() && !dir.is_dir() {
            return Err(anyhow!(
                "listen address {listen:?} is malformed: parent directory {dir:?} \
                 does not exist"
            ));
        }
    }
    if let Ok(md) = std::fs::symlink_metadata(p) {
        if md.file_type().is_socket() {
            std::fs::remove_file(p)
                .with_context(|| format!("cannot remove stale socket {listen:?}"))?;
        } else {
            return Err(anyhow!(
                "listen address {listen:?} exists and is not a socket; refusing to \
                 replace it"
            ));
        }
    }
    UnixListener::bind(p).with_context(|| format!("cannot listen on {listen:?}"))
}

fn reader_loop(slot: usize, conn: std::os::unix::net::UnixStream, tx: Sender<Msg>) {
    let mut r = BufReader::new(conn);
    let mut line = String::new();
    loop {
        line.clear();
        match r.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => {
                let text = line.trim_end_matches('\n').to_string();
                if text.trim().is_empty() {
                    continue;
                }
                if tx.send(Msg::Line { slot, text }).is_err() {
                    return; // driver gone; nothing left to feed
                }
            }
            // A torn connection is an EOF with a reason the per-line
            // quarantine already covered as far as data goes.
            Err(_) => break,
        }
    }
    let _ = tx.send(Msg::Eof { slot });
}

/// The merge driver's per-run emission state.
struct Driver<'a> {
    fleet: FleetMerge,
    horizon: ReorderHorizon,
    sinks: &'a mut [Box<dyn ReportSink>],
    /// Global ids already announced downstream.
    announced: FxHashSet<u32>,
}

impl Driver<'_> {
    /// Ensure `slot` exists in both the merge core and the horizon
    /// (readers are numbered by accept order; their first line may
    /// arrive in any order).
    fn ensure(&mut self, slot: usize) {
        while self.fleet.producer_count() <= slot {
            let n = self.fleet.producer_count();
            self.fleet.register(&format!("producer-{n}"));
        }
        self.horizon.ensure(slot);
    }

    fn on_line(&mut self, slot: usize, text: &str) -> Result<()> {
        self.ensure(slot);
        match self.fleet.ingest_line(slot, text) {
            Some(Ingested::Window {
                index,
                slices,
                drained,
                drops,
                paths,
                ..
            }) => {
                let part = WindowPart {
                    producer: slot,
                    slices,
                    drained,
                    drops,
                    paths,
                };
                if let Offer::Late(part) = self.horizon.offer(part, index) {
                    // Past the horizon: out of the live merged stream,
                    // but never out of the final report.
                    self.fleet.note_late(slot);
                    self.fleet.fold(&part.paths);
                }
                self.drain_ready()
            }
            Some(Ingested::Session { apps }) => {
                if !apps.is_empty() {
                    self.fleet.rename(slot, apps.join("+"));
                }
                Ok(())
            }
            // Symbol announcements update the merge core's tables as a
            // side effect of validation; the downstream re-announcement
            // happens per merged window so it stays paired with the
            // partials that need it.
            Some(Ingested::Symbols(_)) | Some(Ingested::Other) | None => Ok(()),
        }
    }

    fn on_eof(&mut self, slot: usize) -> Result<()> {
        self.ensure(slot);
        self.horizon.eof(slot);
        self.drain_ready()
    }

    fn drain_ready(&mut self) -> Result<()> {
        for w in self.horizon.ready() {
            self.emit_window(w)?;
        }
        Ok(())
    }

    /// Close one fleet window: pairwise-merge the buffered parts
    /// (producer-count-invariant by associativity + `first_seen`
    /// reconciliation), announce any global ids new to the merged
    /// stream, re-emit as one merged `shard_window`, fold into the
    /// cumulative total.
    fn emit_window(&mut self, w: ClosedWindow) -> Result<()> {
        let merged = merge_tree(w.parts);
        let mut fresh: Vec<SymbolEntry> = Vec::new();
        for p in &merged {
            if !self.announced.insert(p.stack_id) {
                continue;
            }
            fresh.push(SymbolEntry {
                stack_id: p.stack_id,
                frames: self.fleet.resolve(p.stack_id).to_vec(),
                rendered: self
                    .fleet
                    .rendering(p.stack_id)
                    .map(|r| r.to_vec())
                    .unwrap_or_default(),
            });
        }
        if !fresh.is_empty() {
            emit(
                self.sinks,
                &ReportEvent::Symbols(SymbolsEvent { entries: &fresh }),
            )?;
        }
        emit(
            self.sinks,
            &ReportEvent::ShardWindow(ShardWindowEvent {
                index: w.index,
                shard: 0,
                slices: w.slices,
                drained: w.drained,
                drops: w.drops,
                paths: &merged,
            }),
        )?;
        self.fleet.fold(&merged);
        Ok(())
    }
}

fn emit(sinks: &mut [Box<dyn ReportSink>], ev: &ReportEvent<'_>) -> Result<()> {
    for s in sinks.iter_mut() {
        s.on_event(ev)?;
    }
    Ok(())
}

/// Run the fleet service: bind, accept `cfg.producers` connections,
/// merge until every producer disconnects, and return the rendered
/// fleet report. The merged session streams through `sinks` as it
/// happens.
pub fn serve(cfg: &ServeConfig, sinks: &mut [Box<dyn ReportSink>]) -> Result<String> {
    let listener = bind(&cfg.listen)?;
    serve_on(listener, cfg, sinks)
}

/// [`serve`] on an already-bound listener (tests bind their own).
pub fn serve_on(
    listener: UnixListener,
    cfg: &ServeConfig,
    sinks: &mut [Box<dyn ReportSink>],
) -> Result<String> {
    let nproducers = cfg.producers.max(1);
    let info = SessionInfo {
        mode: SessionMode::Live,
        apps: Vec::new(),
        shards: 1,
        window_ns: None,
        config: GappConfig::default(),
    };
    emit(sinks, &ReportEvent::SessionStart(&info))?;

    let mut fleet = FleetMerge::new();
    if let Some(base) = cfg.compact_base {
        fleet.compact(base);
    }
    let mut driver = Driver {
        fleet,
        horizon: ReorderHorizon::new(cfg.horizon),
        sinks,
        announced: FxHashSet::default(),
    };
    // Every expected producer holds the horizon open from the start: a
    // fleet window may only close once each of them is past it or done.
    // A producer that merely hasn't connected yet is neither — without
    // this, a fast peer could close (and late-mark) windows the slow
    // connector still owes parts for.
    driver.ensure(nproducers - 1);

    std::thread::scope(|s| -> Result<()> {
        let (tx, rx) = channel::<Msg>();
        // Acceptor: number producers by accept order and hand each its
        // own blocking reader thread (nested scoped spawn — the PR 8
        // lane shape with connections instead of ring shards). Dropping
        // the last sender is the shutdown signal for the driver.
        s.spawn(move || {
            for slot in 0..nproducers {
                match listener.accept() {
                    Ok((conn, _)) => {
                        let tx = tx.clone();
                        s.spawn(move || reader_loop(slot, conn, tx));
                    }
                    Err(_) => {
                        let _ = tx.send(Msg::Eof { slot });
                    }
                }
            }
        });
        // The merge driver: single-threaded fold over the interleaved
        // line stream, exactly one merged session out the other side.
        while let Ok(msg) = rx.recv() {
            match msg {
                Msg::Line { slot, text } => driver.on_line(slot, &text)?,
                Msg::Eof { slot } => driver.on_eof(slot)?,
            }
        }
        Ok(())
    })?;

    // All producers disconnected: flush whatever the horizon still
    // holds, then close the merged session.
    for slot in 0..nproducers {
        driver.on_eof(slot)?;
    }
    let Driver { fleet, sinks, .. } = driver;
    emit(sinks, &ReportEvent::SessionEnd { runtime_ns: 0 })?;
    for s in sinks.iter_mut() {
        s.finish()?;
    }
    Ok(fleet.render(cfg.top))
}
