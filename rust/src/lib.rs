//! # GAPP — Generic Automatic Parallel Profiler (ICPE '20 reproduction)
//!
//! A full-system reproduction of *GAPP: A Fast Profiler for Detecting
//! Serialization Bottlenecks in Parallel Linux Applications* (Nair & Field,
//! ICPE 2020), built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the profiler pipeline and every substrate it
//!   needs: a discrete-event Linux-scheduler simulator ([`simkernel`]), an
//!   eBPF-like tracing framework ([`ebpf`]), a synthetic parallel-workload
//!   system with 13 applications ([`workload`]), the GAPP probes and
//!   user-space engine ([`gapp`]), baseline profilers ([`baselines`]) and
//!   the experiment harness ([`experiments`]).
//! * **Layer 2** — a JAX analysis graph (`python/compile/model.py`) that
//!   batches GAPP's CMetric bookkeeping into activity-matrix reductions,
//!   AOT-lowered to HLO text at build time.
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) for the fused
//!   `Aᵀ(t/n)` / `Aᵀt` aggregation and top-K ranking.
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT (`xla` crate) and
//! serves them on the profiling hot path — Python never runs at profile
//! time.
//!
//! See `DESIGN.md` for the substitution table (real kernel/eBPF/Parsec →
//! simulated substrates) and the per-experiment index, and `EXPERIMENTS.md`
//! for paper-vs-measured results.

pub mod util;
pub mod simkernel;
pub mod ebpf;
pub mod workload;
pub mod gapp;
pub mod fleet;
pub mod runtime;
pub mod baselines;
pub mod experiments;
pub mod scenario;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
