//! Offline stand-in for the `anyhow` crate.
//!
//! The vendored offline registry this workspace builds against does not
//! carry `anyhow`, so this crate implements the (small) subset the
//! workspace uses with the same names and semantics: [`Error`],
//! [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros and the
//! [`Context`] extension trait. Like the real crate, `Error` does *not*
//! implement `std::error::Error` (that is what makes the blanket
//! `From<E: std::error::Error>` impl legal), `{:#}` renders the full
//! context chain and `{}` only the outermost message.

use std::error::Error as StdError;
use std::fmt;

/// A dynamically-typed error with a context chain.
pub struct Error {
    /// Root message (innermost cause we were constructed from).
    msg: String,
    /// Contexts added via [`Context`], innermost first.
    context: Vec<String>,
    /// Original error object, kept for its `source()` chain.
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error {
            msg: m.to_string(),
            context: Vec::new(),
            source: None,
        }
    }

    /// Attach an outer context message.
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.context.push(c.to_string());
        self
    }

    /// Root cause message (innermost).
    pub fn root_cause_msg(&self) -> &str {
        &self.msg
    }

    fn write_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for c in self.context.iter().rev() {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.msg)?;
        // Walk the wrapped error's own source chain, if any.
        let mut src = self.source.as_ref().and_then(|e| e.source());
        while let Some(s) = src {
            write!(f, ": {s}")?;
            src = s.source();
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    /// `{}` renders the outermost message, `{:#}` the full chain —
    /// matching the real anyhow's formatting contract.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            self.write_chain(f)
        } else {
            match self.context.last() {
                Some(c) => write!(f, "{c}"),
                None => write!(f, "{}", self.msg),
            }
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_chain(f)
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error {
            msg: e.to_string(),
            context: Vec::new(),
            source: Some(Box::new(e)),
        }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($fmt:literal, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)+) => { return Err($crate::anyhow!($($t)+)) };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($rest:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($rest)+));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e).context("opening artifact")
    }

    #[test]
    fn context_chain_renders() {
        let e = fails_io().unwrap_err();
        assert_eq!(format!("{e}"), "opening artifact");
        assert_eq!(format!("{e:#}"), "opening artifact: gone");
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("bad {}", 7);
        assert_eq!(format!("{e}"), "bad 7");
        fn f(x: u32) -> Result<u32> {
            ensure!(x > 2, "x too small: {x}");
            if x > 100 {
                bail!("x too big");
            }
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert!(format!("{}", f(1).unwrap_err()).contains("too small"));
        assert!(format!("{}", f(101).unwrap_err()).contains("too big"));
    }

    #[test]
    fn question_mark_converts() {
        fn g() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(g().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }
}
